//! Simulation configuration shared by all engines.

use crate::compress::budget::ErrorPolicy;
use crate::compress::{Codec, CodecKind};
use crate::memory::FaultPlan;
use crate::pipeline::PipelineConfig;
use crate::types::{Error, Precision, Result};
use std::path::PathBuf;

/// Which gate-application backend executes state-vector updates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// Tuned rust kernels (production hot path).
    Native,
    /// AOT-compiled JAX/Pallas HLO artifacts via PJRT (the three-layer
    /// architecture's L1/L2 product; requires `make artifacts`).
    Xla,
}

impl std::str::FromStr for Backend {
    type Err = Error;
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "native" => Ok(Backend::Native),
            "xla" => Ok(Backend::Xla),
            other => Err(Error::Config(format!("unknown backend {other:?}"))),
        }
    }
}

/// Whether the engines run each worker's group chain on the overlapped
/// decode/apply/encode phase pipeline (§4.2 overhead concealment).
///
/// `Auto` — the default since the persistent-pool refactor — decides *per
/// stage* at plan time from [`auto_overlap`]: group size × the codec cost
/// measured during block initialization. `On`/`Off` (CLI `--overlap` /
/// `--no-overlap`) pin the choice for every stage.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum OverlapMode {
    /// Per-stage heuristic (the default).
    #[default]
    Auto,
    /// Always pipeline (the old `--overlap` opt-in).
    On,
    /// Always sequential per-worker chains.
    Off,
}

impl OverlapMode {
    /// The pinned mode for an explicit on/off choice.
    pub fn pinned(on: bool) -> Self {
        if on {
            OverlapMode::On
        } else {
            OverlapMode::Off
        }
    }

    /// Resolve the mode against the heuristic's verdict for one stage.
    pub fn engaged(self, heuristic: bool) -> bool {
        match self {
            OverlapMode::On => true,
            OverlapMode::Off => false,
            OverlapMode::Auto => heuristic,
        }
    }

    /// True for [`OverlapMode::Auto`].
    pub fn is_auto(self) -> bool {
        matches!(self, OverlapMode::Auto)
    }
}

/// Auto-enable threshold: estimated concealable codec time per group chain
/// below which the overlapped pipeline is declined. Calibrated against the
/// fig11 overlap study: at the study's smoke geometry (2^14-amplitude
/// groups, point-wise codec at single-digit ns/amp) a chain conceals
/// ≈0.5–1.5 ms — an order of magnitude above this floor — while the
/// handshake machinery (condvar wakeups, worst-case 500 µs poll) makes
/// chains concealing ≲150 µs a net loss. See `fig11_auto_enable` for the
/// measured crossover.
pub const OVERLAP_AUTO_MIN_CONCEAL_NS: f64 = 150_000.0;

/// The stage-plan-time overlap heuristic (ROADMAP "overlap auto-enable"):
/// estimate the codec time a chain could conceal — `group_len` amplitudes
/// × 2 planes × (decompress + compress) ≈ `4 × group_len ×
/// codec_ns_per_amp` — and engage the pipeline only when it clears
/// [`OVERLAP_AUTO_MIN_CONCEAL_NS`]. A stage with fewer than two groups has
/// nothing to pipeline (the ring never holds two chains) and always
/// declines. `codec_ns_per_amp` is measured by the engines while
/// compressing the initial blocks, so a raw (pass-through) codec or a fast
/// machine genuinely lowers the estimate.
pub fn auto_overlap(group_len: usize, num_groups: usize, codec_ns_per_amp: f64) -> bool {
    if num_groups < 2 {
        return false;
    }
    let concealable_ns = 4.0 * group_len as f64 * codec_ns_per_amp;
    concealable_ns >= OVERLAP_AUTO_MIN_CONCEAL_NS
}

/// Full engine configuration. `Default` reproduces the paper's settings
/// (point-wise relative 1e-3, pre-scan on, pipelined).
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// `b`: log2 of SV block length (paper's "SV block size" knob, Fig. 15).
    pub block_qubits: usize,
    /// Algorithm-1 inner-size threshold (paper's "inner size" knob, Fig. 15).
    pub inner_size: usize,
    /// Plane codec (kind + bound + prescan).
    pub codec: Codec,
    /// Gate-application backend.
    pub backend: Backend,
    /// Pipeline shape (devices x streams, Fig. 12/13 knobs).
    pub pipeline: PipelineConfig,
    /// Primary-tier budget in bytes; `None` = unlimited.
    pub memory_budget: Option<usize>,
    /// Secondary-tier directory; enables spilling when the budget is set.
    pub spill_dir: Option<PathBuf>,
    /// State-vector precision.
    pub precision: Precision,
    /// Directory holding `manifest.json` + HLO artifacts (Xla backend).
    pub artifacts_dir: PathBuf,
    /// Gate fusion + batched stage application (`circuit::fusion`,
    /// `gates::fused`). Only takes effect on backends whose applier
    /// reports [`super::GateApplier::supports_fusion`].
    pub fusion: bool,
    /// Fused-unitary width cap `k` (clamped to `1..=MAX_FUSED_QUBITS`).
    pub max_fuse_qubits: usize,
    /// `log2(amplitudes)` per cache tile in the batched kernel.
    pub tile_bits: usize,
    /// Worker threads per plane sweep inside gate application (1 = sweep
    /// on the pipeline worker itself; raise it when groups are fewer than
    /// cores, e.g. sequential pipelines on big planes). Like `fusion`,
    /// only takes effect on backends whose applier reports
    /// [`super::GateApplier::supports_fusion`]; others sweep serially.
    pub apply_workers: usize,
    /// Lock shards in the two-level [`crate::memory::BlockStore`]
    /// (rounded up to a power of two). 1 approximates the old
    /// single-lock store's contention profile.
    pub store_shards: usize,
    /// SV groups the store's prefetcher stages ahead of the pipeline
    /// workers (0 disables prefetching).
    pub prefetch_depth: usize,
    /// Spill evictions synchronously on the worker thread instead of the
    /// background writer (the pre-refactor behaviour, minus the
    /// I/O-under-lock; baseline knob for the fig09 concurrency study).
    pub sync_spill: bool,
    /// Overlapped group chains: run each worker's fetch+decompress,
    /// gate-apply, and compress+store phases on the persistent three-thread
    /// phase pipeline ([`crate::pipeline::PhasePool`]) over a ring of
    /// scratch slots, so codec time and store I/O are concealed behind gate
    /// application (§4.2's "pipeline" contribution). `Auto` (default)
    /// decides per stage from group size × measured codec cost
    /// ([`auto_overlap`]); `Off` = the strictly sequential per-worker chain
    /// (identical numbers to the pre-overlap engine; the right call for
    /// tiny groups, where handshake overhead exceeds codec time).
    pub overlap: OverlapMode,
    /// Scratch slots per worker ring when overlap engages: how many group
    /// chains may be in flight per worker. 2 = classic double buffering;
    /// 1 degenerates to a handoff-serialized chain (parity testing). With
    /// `pipeline_depth_auto` this is only the *starting* depth.
    pub pipeline_depth: usize,
    /// Adapt `pipeline_depth` per stage from observed handshake-stall
    /// imbalance (AIMD, [`crate::pipeline::RingDepthController`]) instead
    /// of holding it fixed. The CLI enables this whenever
    /// `--pipeline-depth` is not given explicitly.
    pub pipeline_depth_auto: bool,
    /// Spill-aware scheduling: reorder each stage's groups so groups
    /// whose blocks are already primary-resident run first (the store
    /// knows — [`crate::memory::BlockStore::residency_rank`]), shrinking
    /// the prefetcher's cold-start window. No-op without a memory budget.
    pub spill_aware: bool,
    /// Adapt `prefetch_depth` per stage (AIMD on hit/miss ratio and spill
    /// stall) instead of holding it fixed; `prefetch_depth` is then only
    /// the starting depth. The CLI enables this whenever
    /// `--prefetch-depth` is not given explicitly.
    pub prefetch_auto: bool,
    /// Fault-injection plan for the spill/store layer (CLI `--fault-plan`,
    /// env `BMQSIM_FAULT_PLAN`): scripted and seeded-probabilistic I/O
    /// faults exercising the recovery machinery. `None` = no injection.
    pub fault_plan: Option<FaultPlan>,
    /// Overflow stripe for ENOSPC graceful degradation: when the primary
    /// spill file's device fills, eviction retargets this directory
    /// (ideally a different filesystem) before renegotiating the budget.
    pub spill_fallback_dir: Option<PathBuf>,
    /// Pin the codec/gate kernels to the scalar oracle for this run (CLI
    /// `--no-simd`; the `BMQSIM_NO_SIMD` env var does the same
    /// process-wide). Vector and scalar kernels are byte-identical, so
    /// this is a diagnostic/benchmark knob, never a correctness one.
    pub no_simd: bool,
    /// Cross-stage pipeline overlap: let the next stage's decode phase
    /// start while the previous stage's encoders drain, instead of a full
    /// per-stage barrier. Decode of a group that shares blocks with the
    /// previous stage's unfinished tail waits on a per-item boundary gate
    /// (`sim::BoundaryGate`); disjoint groups flow immediately. `Auto`
    /// (default) follows the overlap pipeline itself: cross-stage engages
    /// whenever `overlap` is not pinned `Off`. CLI `--cross-stage` /
    /// `--no-cross-stage` pin it. Per-gate engines (`Sc19Sim`) ignore it:
    /// each gate's groups tile every block, so no group is ever disjoint
    /// from the previous stage and the barrier is optimal there.
    pub cross_stage: OverlapMode,
    /// Checkpoint root directory (CLI `--checkpoint-dir`). `Some` enables
    /// crash-consistent stage-boundary snapshots: every
    /// `checkpoint_every` completed stages the engine quiesces the
    /// pipeline window, flushes the write-back queue, and persists all
    /// live blocks plus an atomically renamed manifest
    /// ([`crate::memory::checkpoint`]). `None` = no checkpointing.
    pub checkpoint_dir: Option<PathBuf>,
    /// Stage-boundary snapshot cadence (CLI `--checkpoint-every N`, min
    /// 1): checkpoint after every N completed stages (per-gate engines
    /// count gates). Ignored without `checkpoint_dir`.
    pub checkpoint_every: usize,
    /// Resume from the newest intact checkpoint under this directory
    /// (CLI `--resume DIR`): validate the manifest's config fingerprint,
    /// rehydrate the block store, and continue from the saved stage
    /// cursor to a terminal state byte-identical to the uninterrupted
    /// run. A fingerprint mismatch is a typed [`Error::Checkpoint`].
    pub resume_from: Option<PathBuf>,
    /// Retained checkpoints (CLI `--checkpoint-keep`, min 1): after each
    /// commit, older `ckpt-*` directories beyond the N most recent are
    /// pruned. Two (default) guarantees a fallback snapshot survives a
    /// kill during the next checkpoint's write.
    pub checkpoint_keep: usize,
    /// Watchdog on stage-boundary waits (CLI `--stall-timeout-ms`;
    /// `None` = off, the default): epoch-drain and cross-stage boundary
    /// waiters give up after this long without progress and surface a
    /// typed error with a progress-counter dump instead of hanging the
    /// run forever (e.g. under a `stall@write` fault plan).
    pub stall_timeout_ms: Option<u64>,
    /// Whole-run fidelity target (CLI `--fidelity-target`, e.g. `0.999`):
    /// turn fidelity from an observed output into a controlled input.
    /// `Some` engages the [`crate::compress::budget::BudgetController`] —
    /// per-encode bounds are derived from an error-budget ledger instead
    /// of the fixed `codec.error_bound`, and the memory tier may
    /// recompress cold blocks at controller-approved looser bounds
    /// instead of spilling them. Requires the point-wise relative codec
    /// ([`SimConfig::validate`] rejects other kinds). `None` (default) =
    /// the fixed global bound, exactly the pre-controller behaviour.
    pub fidelity_target: Option<f64>,
    /// How the error budget is split across blocks when a fidelity target
    /// is set (CLI `--error-policy {global,amplitude}`): `Global` = one
    /// uniform target-derived bound per stage; `Amplitude` = per-block
    /// bounds shaped by amplitude mass (tight on heavy blocks, loose on
    /// near-zero ones). Ignored without `fidelity_target`.
    pub error_policy: ErrorPolicy,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            block_qubits: 14,
            inner_size: 2,
            codec: Codec::paper_default(),
            backend: Backend::Native,
            pipeline: PipelineConfig::new(1, 2),
            memory_budget: None,
            spill_dir: None,
            precision: Precision::F64,
            artifacts_dir: PathBuf::from("artifacts"),
            fusion: true,
            max_fuse_qubits: crate::circuit::MAX_FUSED_QUBITS,
            tile_bits: crate::gates::fused::DEFAULT_TILE_BITS,
            apply_workers: 1,
            store_shards: 8,
            prefetch_depth: 4,
            sync_spill: false,
            overlap: OverlapMode::Auto,
            pipeline_depth: 2,
            pipeline_depth_auto: true,
            spill_aware: true,
            prefetch_auto: false,
            fault_plan: None,
            spill_fallback_dir: None,
            no_simd: false,
            cross_stage: OverlapMode::Auto,
            checkpoint_dir: None,
            checkpoint_every: 1,
            resume_from: None,
            checkpoint_keep: 2,
            stall_timeout_ms: None,
            fidelity_target: None,
            error_policy: ErrorPolicy::Global,
        }
    }
}

impl SimConfig {
    /// Clamp the block size for small circuits: a block can never exceed
    /// the state, and tiny states get one block.
    pub fn effective_block_qubits(&self, n_qubits: usize) -> usize {
        self.block_qubits.min(n_qubits)
    }

    /// Store tuning derived from the config (shards, prefetch, spill
    /// mode), handed to [`crate::memory::BlockStore::with_options`].
    pub fn store_options(&self) -> crate::memory::StoreOptions {
        crate::memory::StoreOptions {
            shards: self.store_shards.max(1),
            prefetch_depth: self.prefetch_depth,
            async_spill: !self.sync_spill,
            auto_depth: self.prefetch_auto,
            fault_plan: self.fault_plan.clone().or_else(FaultPlan::from_env),
            fallback_dir: self.spill_fallback_dir.clone(),
            ..crate::memory::StoreOptions::default()
        }
    }

    /// Validate against a circuit size.
    pub fn validate(&self, n_qubits: usize) -> Result<()> {
        if n_qubits == 0 || n_qubits > 34 {
            return Err(Error::Config(format!(
                "n_qubits {n_qubits} outside supported range 1..=34"
            )));
        }
        if self.memory_budget.is_some() && self.spill_dir.is_none() {
            // Allowed: it means hard-OOM semantics (Table 2 probing).
        }
        if let Some(target) = self.fidelity_target {
            if !(target > 0.0 && target < 1.0) {
                return Err(Error::Config(format!(
                    "fidelity target {target} outside (0, 1)"
                )));
            }
            if self.codec.kind != CodecKind::PointwiseRel {
                return Err(Error::Config(
                    "fidelity target requires the point-wise relative codec \
                     (the budget ledger is written for per-amplitude relative \
                     bounds; use --codec pointwise)"
                        .into(),
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::CodecKind;

    #[test]
    fn default_matches_paper() {
        let c = SimConfig::default();
        assert_eq!(c.codec.kind, CodecKind::PointwiseRel);
        assert_eq!(c.codec.error_bound, 1e-3);
        assert_eq!(c.block_qubits, 14);
        assert_eq!(c.inner_size, 2);
        assert!(c.fusion);
        assert_eq!(c.max_fuse_qubits, 3);
        assert_eq!(c.apply_workers, 1);
        assert_eq!(c.store_shards, 8);
        assert_eq!(c.prefetch_depth, 4);
        assert!(!c.sync_spill);
        assert_eq!(c.overlap, OverlapMode::Auto, "overlap defaults to the heuristic");
        assert_eq!(c.pipeline_depth, 2);
        assert!(c.pipeline_depth_auto, "ring depth adapts unless pinned");
        assert!(c.spill_aware);
        assert!(!c.prefetch_auto);
        assert!(c.fault_plan.is_none(), "no fault injection by default");
        assert!(c.spill_fallback_dir.is_none());
        assert!(!c.no_simd, "vector kernels on by default");
        assert_eq!(c.cross_stage, OverlapMode::Auto, "cross-stage follows overlap");
        assert!(c.checkpoint_dir.is_none(), "no checkpointing by default");
        assert_eq!(c.checkpoint_every, 1);
        assert!(c.resume_from.is_none());
        assert_eq!(c.checkpoint_keep, 2, "one fallback snapshot is always retained");
        assert!(c.stall_timeout_ms.is_none(), "watchdog off by default");
        assert!(c.fidelity_target.is_none(), "fixed global bound by default");
        assert_eq!(c.error_policy, ErrorPolicy::Global);
        let opts = c.store_options();
        assert_eq!(opts.shards, 8);
        assert!(opts.async_spill);
        assert!(!opts.auto_depth);
        let auto = SimConfig { prefetch_auto: true, ..SimConfig::default() };
        assert!(auto.store_options().auto_depth);
    }

    #[test]
    fn effective_block_clamps() {
        let c = SimConfig::default();
        assert_eq!(c.effective_block_qubits(10), 10);
        assert_eq!(c.effective_block_qubits(20), 14);
    }

    #[test]
    fn backend_parses() {
        assert_eq!("native".parse::<Backend>().unwrap(), Backend::Native);
        assert_eq!("xla".parse::<Backend>().unwrap(), Backend::Xla);
        assert!("cuda".parse::<Backend>().is_err());
    }

    #[test]
    fn validate_bounds() {
        let c = SimConfig::default();
        assert!(c.validate(20).is_ok());
        assert!(c.validate(0).is_err());
        assert!(c.validate(99).is_err());
    }

    #[test]
    fn validate_fidelity_target() {
        let ok = SimConfig { fidelity_target: Some(0.999), ..SimConfig::default() };
        assert!(ok.validate(10).is_ok());
        for bad in [0.0, 1.0, -0.5, 1.5] {
            let c = SimConfig { fidelity_target: Some(bad), ..SimConfig::default() };
            assert!(c.validate(10).is_err(), "target {bad} must be rejected");
        }
        // The ledger math is pointwise-relative only.
        for codec in [Codec::raw(), Codec::absolute(1e-4)] {
            let c = SimConfig {
                fidelity_target: Some(0.999),
                codec,
                ..SimConfig::default()
            };
            assert!(c.validate(10).is_err(), "{} must be rejected", codec.name());
        }
    }

    #[test]
    fn overlap_mode_resolution() {
        assert!(OverlapMode::On.engaged(false));
        assert!(!OverlapMode::Off.engaged(true));
        assert!(OverlapMode::Auto.engaged(true));
        assert!(!OverlapMode::Auto.engaged(false));
        assert_eq!(OverlapMode::pinned(true), OverlapMode::On);
        assert_eq!(OverlapMode::pinned(false), OverlapMode::Off);
        assert!(OverlapMode::Auto.is_auto() && !OverlapMode::On.is_auto());
    }

    #[test]
    fn auto_overlap_boundaries() {
        // Tiny groups never clear the concealment floor.
        assert!(!auto_overlap(1 << 6, 16, 10.0));
        // Codec-heavy large groups do.
        assert!(auto_overlap(1 << 14, 16, 10.0));
        // A single group has nothing to pipeline, whatever the codec cost.
        assert!(!auto_overlap(1 << 20, 1, 1000.0));
        assert!(!auto_overlap(1 << 20, 0, 1000.0));
        // Exact threshold: `>=` engages; a hair below declines.
        let glen = 1usize << 12;
        let ns = OVERLAP_AUTO_MIN_CONCEAL_NS / (4.0 * glen as f64);
        assert!(auto_overlap(glen, 2, ns));
        assert!(!auto_overlap(glen, 2, ns * 0.99));
        // A free codec (raw passthrough measuring ~0) always declines.
        assert!(!auto_overlap(1 << 20, 64, 0.0));
    }
}
