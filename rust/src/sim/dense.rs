//! Dense (uncompressed) state-vector engine — the SV-Sim-class baseline.
//!
//! Holds the full `2^n` state in memory and applies gates in circuit
//! order. This is both the speed/memory baseline of Table 2 / Fig. 10 and
//! the ψ_ideal producer for every fidelity measurement (§5.3).

use super::{GateApplier, NativeApplier, SimConfig, SimResult};
use crate::circuit::Circuit;
use crate::metrics::{Metrics, Phase};
use crate::state::StateVector;
use crate::types::Result;
use std::time::Instant;

/// Dense engine, parameterized by the gate-application backend.
pub struct DenseSim<'a> {
    /// Run configuration (validated at `run` time).
    pub config: SimConfig,
    applier: &'a dyn GateApplier,
}

impl<'a> DenseSim<'a> {
    /// Engine with the native (CPU reference) gate applier.
    pub fn new(config: SimConfig) -> DenseSim<'static> {
        DenseSim { config, applier: &NativeApplier }
    }

    /// Engine with a caller-supplied gate applier (e.g. an accelerator).
    pub fn with_applier(config: SimConfig, applier: &'a dyn GateApplier) -> Self {
        DenseSim { config, applier }
    }

    /// Run the circuit and return the final state + metrics.
    pub fn run(&self, circuit: &Circuit) -> Result<SimResult> {
        self.config.validate(circuit.n_qubits)?;
        let _simd_guard = crate::simd::disable_scope(self.config.no_simd);
        let simd_kernels_at_start = crate::simd::kernels_used();
        let metrics = Metrics::new();
        let t0 = Instant::now();
        let mut state = StateVector::zero_state(circuit.n_qubits)?;
        let bits_of = |g: &crate::circuit::Gate| g.targets().to_vec();
        for gate in &circuit.gates {
            let bits = bits_of(gate);
            metrics.time(Phase::Apply, || {
                self.applier.apply(&mut state.re, &mut state.im, gate, &bits)
            })?;
            metrics.gates_applied.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        }
        let wall = t0.elapsed().as_secs_f64();
        metrics.simd_kernels_used.store(
            crate::simd::kernels_used().saturating_sub(simd_kernels_at_start),
            std::sync::atomic::Ordering::Relaxed,
        );
        let peak = state.len() * self.config.precision.amp_bytes();
        Ok(SimResult {
            engine: "dense",
            circuit_name: circuit.name.clone(),
            n_qubits: circuit.n_qubits,
            wall_secs: wall,
            metrics: metrics.snapshot(wall),
            mem: Default::default(),
            peak_bytes: peak,
            stages: 1,
            state: Some(state),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuit::generators;

    #[test]
    fn ghz_state_amplitudes() {
        let c = generators::ghz_state(10);
        let r = DenseSim::new(SimConfig::default()).run(&c).unwrap();
        let s = r.state.unwrap();
        let h = std::f64::consts::FRAC_1_SQRT_2;
        assert!((s.re[0] - h).abs() < 1e-12);
        assert!((s.re[(1 << 10) - 1] - h).abs() < 1e-12);
        assert!((s.norm_sq() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn cat_equals_ghz() {
        let a = DenseSim::new(SimConfig::default())
            .run(&generators::cat_state(8))
            .unwrap();
        let b = DenseSim::new(SimConfig::default())
            .run(&generators::ghz_state(8))
            .unwrap();
        let f = a.state.unwrap().fidelity(b.state.as_ref().unwrap());
        assert!((f - 1.0).abs() < 1e-12);
    }

    #[test]
    fn qft_of_zero_is_uniform() {
        let c = generators::qft(6);
        let r = DenseSim::new(SimConfig::default()).run(&c).unwrap();
        let s = r.state.unwrap();
        let want = (1.0 / 64.0f64).sqrt();
        for i in 0..64 {
            assert!((s.amplitude(i).abs() - want).abs() < 1e-12, "i={i}");
        }
    }

    #[test]
    fn all_benchmarks_stay_normalized() {
        for name in generators::ALL {
            let c = generators::build(name, 8, 3).unwrap();
            let r = DenseSim::new(SimConfig::default()).run(&c).unwrap();
            let n = r.state.unwrap().norm_sq();
            assert!((n - 1.0).abs() < 1e-9, "{name}: norm {n}");
            assert_eq!(r.metrics.gates_applied as usize, c.len());
        }
    }

    #[test]
    fn bv_recovers_hidden_string() {
        // BV's output on the query register equals the hidden string; our
        // generator draws it from seed, so just check the state is a basis
        // state on the query register (prob mass on exactly 2 indices that
        // differ only in the ancilla).
        let c = generators::bv(9, 1234);
        let r = DenseSim::new(SimConfig::default()).run(&c).unwrap();
        let s = r.state.unwrap();
        let mut heavy: Vec<usize> = (0..s.len()).filter(|&i| s.probability(i) > 1e-6).collect();
        heavy.sort_unstable();
        assert!(heavy.len() <= 2, "{heavy:?}");
        if heavy.len() == 2 {
            assert_eq!(heavy[0] ^ heavy[1], 1 << 8, "ancilla bit");
        }
    }
}
