//! CI bench-regression gate over the `BENCH_*.json` trajectory.
//!
//! Every CI run emits machine-readable bench artifacts, but until this
//! gate nothing ever *compared* them — the perf trajectory was invisible.
//! Following SC19's and MEMQSim's observation that compression-overhead
//! **ratios** (not absolutes) are the quantity to track, the gate pins
//! only ratio-shaped metrics — into-vs-alloc speedup, fused-vs-unfused
//! throughput ratio, spill fraction, pipeline occupancy — which are stable
//! across runner hardware, and ignores the noisy absolute numbers
//! (GB/s, wall seconds) entirely.
//!
//! Committed baselines live in `rust/bench_baselines/`. A fresh smoke-mode
//! artifact regressing a gated metric by more than [`DEFAULT_TOLERANCE`]
//! fails the build (`bin/bench_check` exits non-zero). To re-pin after an
//! intentional perf change, run the smokes and then
//! `BENCH_BASELINE_REFRESH=1 cargo run --release --bin bench_check`.

use crate::runtime::Json;
use std::path::{Path, PathBuf};

/// Maximum tolerated relative regression on a gated metric (smoke mode).
pub const DEFAULT_TOLERANCE: f64 = 0.25;

/// What "worse" means for a gated metric. All current gates are
/// `HigherBetter` floors: each metric is a ratio whose collapse means a
/// subsystem stopped doing its job (the into-path stopped beating the
/// allocating path, fusion stopped paying, the spill machinery stopped
/// engaging, the pipeline stopped overlapping).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Direction {
    /// Fail when `fresh < baseline × (1 − tolerance)`.
    HigherBetter,
    /// Fail when `|fresh − baseline| > |baseline| × tolerance`.
    TwoSided,
}

/// One gated metric: which artifact, where in it, and which direction
/// counts as a regression. `optional` marks metrics that are legitimately
/// absent (rendered `null`) on some runs — occupancy ratios are undefined
/// when a run records no phase time at all — so an absent fresh value is
/// a skip-with-note, not a regression. Non-optional metrics vanishing IS
/// a regression.
pub struct Rule {
    pub file: &'static str,
    pub path: &'static [&'static str],
    pub direction: Direction,
    pub optional: bool,
}

/// The gated ratio metrics (ISSUE 5): one stable ratio per artifact.
/// `BENCH_streams.json` is stamped and archived but not gated — its
/// speedup geomean is too close to 1 in smoke mode to pin. The two
/// occupancy gates on `BENCH_overlap.json` pin the barrier pipeline and
/// the cross-stage pipeline separately (ISSUE 8).
pub const RULES: &[Rule] = &[
    Rule {
        file: "BENCH_hotpath.json",
        path: &["group_chain", "speedup"],
        direction: Direction::HigherBetter,
        optional: false,
    },
    Rule {
        file: "BENCH_gates.json",
        path: &["speedup"],
        direction: Direction::HigherBetter,
        optional: false,
    },
    Rule {
        file: "BENCH_memory.json",
        path: &["spill_fraction"],
        direction: Direction::HigherBetter,
        optional: false,
    },
    Rule {
        file: "BENCH_overlap.json",
        path: &["pipeline_occupancy"],
        direction: Direction::HigherBetter,
        optional: true,
    },
    Rule {
        file: "BENCH_overlap.json",
        path: &["cross_stage_occupancy"],
        direction: Direction::HigherBetter,
        optional: true,
    },
    // Adaptive error control (ISSUE 10): the amplitude policy's whole-run
    // compression ratio at the fidelity target, and its normalized margin
    // above the target ((fidelity − target)/(1 − target)). The ratio
    // collapsing means the budget controller stopped converting refunds
    // into looser bounds; the margin collapsing means it is eating into
    // the guarantee.
    Rule {
        file: "BENCH_frontier.json",
        path: &["compression_ratio_at_target"],
        direction: Direction::HigherBetter,
        optional: false,
    },
    Rule {
        file: "BENCH_frontier.json",
        path: &["fidelity_margin"],
        direction: Direction::HigherBetter,
        optional: false,
    },
];

/// Outcome for one gated metric.
pub struct Finding {
    pub file: String,
    pub metric: String,
    pub baseline: f64,
    pub fresh: f64,
    /// Relative change, `(fresh − baseline) / |baseline|`.
    pub rel: f64,
    pub failed: bool,
}

impl std::fmt::Display for Finding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} {}: baseline {:.4}, fresh {:.4} ({:+.1}%) — {}",
            self.file,
            self.metric,
            self.baseline,
            self.fresh,
            100.0 * self.rel,
            if self.failed { "REGRESSED" } else { "ok" }
        )
    }
}

/// Gate configuration: where the fresh artifacts and baselines live,
/// the tolerance, and which fresh files MUST be present (a required file
/// the bench failed to emit is itself a failure).
pub struct CheckConfig {
    pub fresh_dir: PathBuf,
    pub baseline_dir: PathBuf,
    pub tolerance: f64,
    pub required: Vec<String>,
}

impl CheckConfig {
    pub fn new(fresh_dir: impl Into<PathBuf>, baseline_dir: impl Into<PathBuf>) -> Self {
        CheckConfig {
            fresh_dir: fresh_dir.into(),
            baseline_dir: baseline_dir.into(),
            tolerance: DEFAULT_TOLERANCE,
            required: Vec::new(),
        }
    }
}

/// Gate result: per-metric findings plus advisory notes (skipped files,
/// missing baselines).
pub struct Report {
    pub findings: Vec<Finding>,
    pub notes: Vec<String>,
    pub checked_files: usize,
}

impl Report {
    pub fn failures(&self) -> usize {
        self.findings.iter().filter(|f| f.failed).count()
    }
}

fn load_json(path: &Path) -> std::result::Result<Json, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("cannot read {}: {e}", path.display()))?;
    Json::parse(&text).map_err(|e| format!("cannot parse {}: {e}", path.display()))
}

fn lookup(doc: &Json, path: &[&str]) -> Option<f64> {
    let mut cur = doc;
    for key in path {
        cur = cur.get(key)?;
    }
    cur.as_f64()
}

/// The gate's core comparison, one place for both directions so each can
/// be unit-tested even while RULES only exercises one of them.
pub fn regressed(direction: Direction, baseline: f64, fresh: f64, tolerance: f64) -> bool {
    match direction {
        Direction::HigherBetter => fresh < baseline * (1.0 - tolerance),
        Direction::TwoSided => ((fresh - baseline) / baseline.abs()).abs() > tolerance,
    }
}

/// Run the gate. Fresh files that don't exist are skipped (each CI matrix
/// job only produces its own artifact) unless listed in `required`; a
/// gated file without a committed baseline is an error pointing at the
/// refresh workflow.
pub fn run(cfg: &CheckConfig) -> std::result::Result<Report, String> {
    let mut findings = Vec::new();
    let mut notes = Vec::new();
    let mut checked = std::collections::BTreeSet::new();

    for required in &cfg.required {
        if !cfg.fresh_dir.join(required).is_file() {
            return Err(format!(
                "required bench artifact {required} was not emitted (did the bench run?)"
            ));
        }
        if !RULES.iter().any(|r| r.file == required.as_str()) {
            notes.push(format!("{required}: present but carries no gated metrics"));
        }
    }

    for rule in RULES {
        let fresh_path = cfg.fresh_dir.join(rule.file);
        if !fresh_path.is_file() {
            continue; // not produced by this job
        }
        let baseline_path = cfg.baseline_dir.join(rule.file);
        if !baseline_path.is_file() {
            return Err(format!(
                "no committed baseline for {} (expected {}); pin one with \
                 BENCH_BASELINE_REFRESH=1 bench_check",
                rule.file,
                baseline_path.display()
            ));
        }
        let fresh_doc = load_json(&fresh_path)?;
        let baseline_doc = load_json(&baseline_path)?;
        checked.insert(rule.file);
        let metric = rule.path.join(".");

        let Some(baseline) = lookup(&baseline_doc, rule.path) else {
            notes.push(format!(
                "{}: baseline lacks {metric}; re-pin to start gating it",
                rule.file
            ));
            continue;
        };
        if !baseline.is_finite() || baseline == 0.0 {
            notes.push(format!(
                "{}: baseline {metric} = {baseline} is not gateable",
                rule.file
            ));
            continue;
        }
        let fresh = lookup(&fresh_doc, rule.path);
        let Some(fresh) = fresh.filter(|v| v.is_finite()) else {
            if rule.optional {
                // Occupancy-style ratios are undefined (null) on runs that
                // record no phase time; skip rather than flag.
                notes.push(format!("{}: fresh {metric} absent/null; skipped", rule.file));
                continue;
            }
            findings.push(Finding {
                file: rule.file.to_string(),
                metric,
                baseline,
                fresh: f64::NAN,
                rel: f64::NEG_INFINITY,
                failed: true, // a gated metric vanishing IS a regression
            });
            continue;
        };
        let rel = (fresh - baseline) / baseline.abs();
        let failed = regressed(rule.direction, baseline, fresh, cfg.tolerance);
        findings.push(Finding {
            file: rule.file.to_string(),
            metric,
            baseline,
            fresh,
            rel,
            failed,
        });
    }

    Ok(Report { findings, notes, checked_files: checked.len() })
}

/// Re-pin: copy every gated fresh artifact over its committed baseline.
/// Returns how many baselines were refreshed.
pub fn refresh(cfg: &CheckConfig) -> std::result::Result<usize, String> {
    std::fs::create_dir_all(&cfg.baseline_dir)
        .map_err(|e| format!("cannot create {}: {e}", cfg.baseline_dir.display()))?;
    let mut refreshed = 0usize;
    let mut done = std::collections::BTreeSet::new();
    for rule in RULES {
        let fresh_path = cfg.fresh_dir.join(rule.file);
        if !fresh_path.is_file() || !done.insert(rule.file) {
            continue;
        }
        let dst = cfg.baseline_dir.join(rule.file);
        std::fs::copy(&fresh_path, &dst)
            .map_err(|e| format!("cannot copy {} -> {}: {e}", fresh_path.display(), dst.display()))?;
        refreshed += 1;
    }
    Ok(refreshed)
}

/// Append one schema-stamped JSONL line per fresh gated artifact to the
/// committed history file (ISSUE 8 satellite): git sha and schema version
/// are copied out of the artifact itself (every `BENCH_*.json` is stamped
/// at emission), the timestamp is taken here, and only the gated ratio
/// metrics are recorded — the noisy absolutes stay out of the history for
/// the same reason they stay out of the gate. Returns lines appended.
pub fn append_history(cfg: &CheckConfig, history: &Path) -> std::result::Result<usize, String> {
    let date_unix = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let mut lines = Vec::new();
    let mut seen = std::collections::BTreeSet::new();
    for rule in RULES {
        if !seen.insert(rule.file) {
            continue; // one line per artifact, not per rule
        }
        let fresh_path = cfg.fresh_dir.join(rule.file);
        if !fresh_path.is_file() {
            continue;
        }
        let doc = load_json(&fresh_path)?;
        let schema = doc.get("schema_version").and_then(Json::as_f64).unwrap_or(0.0);
        let sha = doc.get("git_sha").and_then(Json::as_str).unwrap_or("unknown");
        let metrics: Vec<String> = RULES
            .iter()
            .filter(|r| r.file == rule.file)
            .filter_map(|r| {
                lookup(&doc, r.path)
                    .filter(|v| v.is_finite())
                    .map(|v| format!("\"{}\": {v:.4}", r.path.join(".")))
            })
            .collect();
        lines.push(format!(
            "{{\"schema_version\": {schema}, \"git_sha\": \"{sha}\", \"date_unix\": \
             {date_unix}, \"file\": \"{}\", \"metrics\": {{{}}}}}",
            rule.file,
            metrics.join(", ")
        ));
    }
    if lines.is_empty() {
        return Ok(0);
    }
    use std::io::Write as _;
    let mut f = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(history)
        .map_err(|e| format!("cannot open {}: {e}", history.display()))?;
    for line in &lines {
        writeln!(f, "{line}").map_err(|e| format!("cannot append {}: {e}", history.display()))?;
    }
    Ok(lines.len())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("bmq-bench-check-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn write(dir: &Path, name: &str, body: &str) {
        std::fs::write(dir.join(name), body).unwrap();
    }

    #[test]
    fn gate_fires_on_synthetic_regression() {
        let fresh = tmp("fire-fresh");
        let base = tmp("fire-base");
        write(&base, "BENCH_gates.json", r#"{"speedup": 3.0}"#);
        write(&fresh, "BENCH_gates.json", r#"{"speedup": 2.0}"#); // −33%
        let report = run(&CheckConfig::new(&fresh, &base)).unwrap();
        assert_eq!(report.failures(), 1);
        assert!(report.findings[0].failed);
        assert!(report.findings[0].rel < -0.25);
    }

    #[test]
    fn gate_passes_within_tolerance_and_on_improvement() {
        let fresh = tmp("pass-fresh");
        let base = tmp("pass-base");
        write(&base, "BENCH_gates.json", r#"{"speedup": 3.0}"#);
        write(&fresh, "BENCH_gates.json", r#"{"speedup": 2.6}"#); // −13%
        let r = run(&CheckConfig::new(&fresh, &base)).unwrap();
        assert_eq!(r.failures(), 0);
        write(&fresh, "BENCH_gates.json", r#"{"speedup": 9.0}"#); // big win
        let r = run(&CheckConfig::new(&fresh, &base)).unwrap();
        assert_eq!(r.failures(), 0);
    }

    #[test]
    fn nested_path_and_vanished_metric() {
        let fresh = tmp("nest-fresh");
        let base = tmp("nest-base");
        write(&base, "BENCH_hotpath.json", r#"{"group_chain": {"speedup": 1.2}}"#);
        write(&fresh, "BENCH_hotpath.json", r#"{"group_chain": {"speedup": 1.15}}"#);
        let r = run(&CheckConfig::new(&fresh, &base)).unwrap();
        assert_eq!(r.failures(), 0);
        // The metric disappearing (e.g. rendered as null) is a failure.
        write(&fresh, "BENCH_hotpath.json", r#"{"group_chain": {"speedup": null}}"#);
        let r = run(&CheckConfig::new(&fresh, &base)).unwrap();
        assert_eq!(r.failures(), 1);
    }

    #[test]
    fn optional_metric_null_is_skipped_but_regression_still_fires() {
        let fresh = tmp("opt-fresh");
        let base = tmp("opt-base");
        write(
            &base,
            "BENCH_overlap.json",
            r#"{"pipeline_occupancy": 0.8, "cross_stage_occupancy": 0.8}"#,
        );
        // Both occupancies null (idle run): skipped with notes, no failures.
        write(
            &fresh,
            "BENCH_overlap.json",
            r#"{"pipeline_occupancy": null, "cross_stage_occupancy": null}"#,
        );
        let r = run(&CheckConfig::new(&fresh, &base)).unwrap();
        assert_eq!(r.failures(), 0);
        assert!(r.notes.iter().any(|n| n.contains("cross_stage_occupancy")));
        // Present-but-collapsed cross-stage occupancy still regresses.
        write(
            &fresh,
            "BENCH_overlap.json",
            r#"{"pipeline_occupancy": 0.8, "cross_stage_occupancy": 0.1}"#,
        );
        let r = run(&CheckConfig::new(&fresh, &base)).unwrap();
        assert_eq!(r.failures(), 1);
        assert!(r.findings.iter().any(|f| f.metric == "cross_stage_occupancy" && f.failed));
    }

    #[test]
    fn refresh_copies_each_file_once_despite_multiple_rules() {
        let fresh = tmp("once-fresh");
        let base = tmp("once-base");
        write(
            &fresh,
            "BENCH_overlap.json",
            r#"{"pipeline_occupancy": 0.7, "cross_stage_occupancy": 0.75}"#,
        );
        let cfg = CheckConfig::new(&fresh, &base);
        assert_eq!(refresh(&cfg).unwrap(), 1, "two rules, one artifact, one copy");
    }

    #[test]
    fn regressed_covers_both_directions() {
        // HigherBetter: a floor — only drops beyond tolerance fail.
        assert!(regressed(Direction::HigherBetter, 2.0, 1.4, 0.25));
        assert!(!regressed(Direction::HigherBetter, 2.0, 1.6, 0.25));
        assert!(!regressed(Direction::HigherBetter, 2.0, 9.0, 0.25), "improvement passes");
        // TwoSided: a band — drift either way beyond tolerance fails
        // (kept for workload-shape invariants a future rule may pin).
        assert!(regressed(Direction::TwoSided, 0.4, 0.1, 0.25));
        assert!(regressed(Direction::TwoSided, 0.4, 0.6, 0.25));
        assert!(!regressed(Direction::TwoSided, 0.4, 0.45, 0.25));
    }

    #[test]
    fn missing_required_artifact_is_an_error() {
        let fresh = tmp("req-fresh");
        let base = tmp("req-base");
        let mut cfg = CheckConfig::new(&fresh, &base);
        cfg.required = vec!["BENCH_gates.json".to_string()];
        assert!(run(&cfg).is_err());
    }

    #[test]
    fn missing_baseline_is_an_error_with_refresh_hint() {
        let fresh = tmp("nobase-fresh");
        let base = tmp("nobase-base");
        write(&fresh, "BENCH_gates.json", r#"{"speedup": 2.0}"#);
        let err = run(&CheckConfig::new(&fresh, &base)).unwrap_err();
        assert!(err.contains("BENCH_BASELINE_REFRESH"), "unhelpful error: {err}");
    }

    #[test]
    fn refresh_repins_and_gate_then_passes() {
        let fresh = tmp("repin-fresh");
        let base = tmp("repin-base");
        write(&base, "BENCH_gates.json", r#"{"speedup": 9.0}"#);
        write(&fresh, "BENCH_gates.json", r#"{"speedup": 2.0}"#);
        let cfg = CheckConfig::new(&fresh, &base);
        assert_eq!(run(&cfg).unwrap().failures(), 1);
        assert_eq!(refresh(&cfg).unwrap(), 1);
        assert_eq!(run(&cfg).unwrap().failures(), 0);
    }

    #[test]
    fn append_history_stamps_one_parseable_line_per_artifact() {
        let fresh = tmp("hist-fresh");
        let base = tmp("hist-base");
        write(
            &fresh,
            "BENCH_overlap.json",
            r#"{"schema_version": 2, "git_sha": "abc1234",
                "pipeline_occupancy": 0.7, "cross_stage_occupancy": 0.75}"#,
        );
        write(&fresh, "BENCH_gates.json", r#"{"schema_version": 2, "speedup": 3.0}"#);
        let hist = fresh.join("bench_history.jsonl");
        let cfg = CheckConfig::new(&fresh, &base);
        assert_eq!(append_history(&cfg, &hist).unwrap(), 2);
        assert_eq!(append_history(&cfg, &hist).unwrap(), 2, "append, not truncate");
        let body = std::fs::read_to_string(&hist).unwrap();
        let lines: Vec<&str> = body.lines().collect();
        assert_eq!(lines.len(), 4);
        for line in lines {
            let doc = Json::parse(line).expect("history line must be valid JSON");
            assert!(doc.get("date_unix").and_then(Json::as_f64).is_some());
            assert!(doc.get("file").and_then(Json::as_str).is_some());
            assert!(doc.get("metrics").and_then(Json::as_obj).is_some());
        }
        let overlap_line = body.lines().find(|l| l.contains("BENCH_overlap")).unwrap();
        let doc = Json::parse(overlap_line).unwrap();
        assert_eq!(doc.get("git_sha").and_then(Json::as_str), Some("abc1234"));
        let occ = doc
            .get("metrics")
            .and_then(|m| m.get("cross_stage_occupancy"))
            .and_then(Json::as_f64)
            .unwrap();
        assert!((occ - 0.75).abs() < 1e-9);
    }

    #[test]
    fn ungated_file_is_skipped_with_note() {
        let fresh = tmp("ungated-fresh");
        let base = tmp("ungated-base");
        write(&fresh, "BENCH_streams.json", r#"{"n": 12}"#);
        let mut cfg = CheckConfig::new(&fresh, &base);
        cfg.required = vec!["BENCH_streams.json".to_string()];
        let r = run(&cfg).unwrap();
        assert_eq!(r.failures(), 0);
        assert!(r.notes.iter().any(|n| n.contains("no gated metrics")));
    }
}
