//! Experiment harness: one function per paper table/figure, shared by the
//! `cargo bench` targets and the CLI's `report` subcommand.
//!
//! Scale note (DESIGN.md): the paper runs 23-33 qubits on 128 GB + GPUs;
//! this testbed scales qubit counts and memory budgets down proportionally.
//! Each function returns printable [`Table`]s whose *shape* (who wins, by
//! roughly what factor, where crossovers fall) is the reproduction target.

use crate::circuit::generators;
use crate::compress::{Codec, CodecKind};
use crate::metrics::Table;
use crate::pipeline::PipelineConfig;
use crate::sim::{BmqSim, DenseSim, OverlapMode, Sc19Sim, SimConfig, SimResult};
use crate::types::{fmt_bytes, standard_memory_bytes, Precision, Result, SplitMix64};
use std::time::Instant;

pub mod check;

/// Default benchmark seed (fixed: experiments are reproducible).
pub const SEED: u64 = 0xB39_51B;

/// True when `BENCH_SMOKE` is set to a non-empty value other than `0`:
/// self-timed benches shrink problem sizes/reps so CI can exercise them
/// end-to-end (and still emit their `BENCH_*.json`) in seconds.
pub fn bench_smoke() -> bool {
    matches!(std::env::var("BENCH_SMOKE"), Ok(v) if !v.is_empty() && v != "0")
}

/// Shared stopwatch for the self-timed perf benches: warmup calls (one,
/// plus a second when `reps > 1` so branch predictors and the allocator
/// settle), then the *minimum* of `reps` individually-timed calls. Min-of-N
/// is the standard noise filter for throughput benches: external
/// interference only ever adds time, so the minimum is the best estimate
/// of the true cost.
pub fn time_it(reps: usize, mut f: impl FnMut()) -> f64 {
    f();
    if reps > 1 {
        f();
    }
    let mut best = f64::INFINITY;
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        best = best.min(t0.elapsed().as_secs_f64());
    }
    best
}

/// Minimal JSON writers shared by the self-timed perf benches (the vendor
/// set has no serde; `runtime::Json` is parse-only). Values are
/// `(key, already-rendered-JSON-value)` pairs.
pub mod bench_json {
    /// Version of the BENCH_*.json envelope. Bump when a gated metric is
    /// renamed/moved so trajectory joins across PRs can detect the break.
    /// v2 added the `schema_version`/`git_sha` stamp itself; v3 switched
    /// `time_it` to min-of-N timing and added `simd_kernels_used`.
    pub const BENCH_SCHEMA_VERSION: u32 = 3;

    /// Render an object from already-rendered value strings.
    pub fn obj(fields: &[(String, String)]) -> String {
        let inner: Vec<String> =
            fields.iter().map(|(k, v)| format!("\"{k}\": {v}")).collect();
        format!("{{{}}}", inner.join(", "))
    }

    /// Render a finite number (4 decimal places) or `null`.
    pub fn num(x: f64) -> String {
        if x.is_finite() {
            format!("{x:.4}")
        } else {
            "null".to_string()
        }
    }

    /// Commit id stamped into every artifact so BENCH trajectories are
    /// joinable across PRs: `GITHUB_SHA` in CI, `git rev-parse HEAD`
    /// locally, `"unknown"` outside a checkout.
    pub fn git_sha() -> String {
        if let Ok(sha) = std::env::var("GITHUB_SHA") {
            if !sha.is_empty() {
                return sha;
            }
        }
        std::process::Command::new("git")
            .args(["rev-parse", "HEAD"])
            .output()
            .ok()
            .filter(|o| o.status.success())
            .and_then(|o| String::from_utf8(o.stdout).ok())
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .unwrap_or_else(|| "unknown".to_string())
    }

    /// Guard for bench mains: a study that failed leaves its field vec
    /// empty (`print_experiment` already reported why). An acceptance
    /// artifact must never go missing silently, so die instead of writing
    /// a hollow file.
    pub fn require_fields(artifact: &str, fields: &[(String, String)]) {
        if fields.is_empty() {
            eprintln!("study failed; {artifact} not written");
            std::process::exit(1);
        }
    }

    /// Stamp (`schema_version`, `git_sha`) and write one `BENCH_*.json`
    /// artifact. Exits non-zero on write failure — an acceptance artifact
    /// must never go missing silently.
    pub fn write_bench_file(path: &str, fields: &[(String, String)]) {
        let mut all: Vec<(String, String)> = vec![
            ("schema_version".to_string(), BENCH_SCHEMA_VERSION.to_string()),
            ("git_sha".to_string(), format!("\"{}\"", git_sha())),
            ("simd_kernels_used".to_string(), crate::simd::kernels_used().to_string()),
        ];
        all.extend_from_slice(fields);
        let doc = obj(&all);
        match std::fs::write(path, doc + "\n") {
            Ok(()) => println!("wrote {path}"),
            Err(e) => {
                eprintln!("could not write {path}: {e}");
                std::process::exit(1);
            }
        }
    }
}

fn spill_dir() -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("bmqsim-bench-{}", std::process::id()));
    let _ = std::fs::create_dir_all(&d);
    d
}

fn cfg(block_qubits: usize, inner: usize) -> SimConfig {
    SimConfig { block_qubits, inner_size: inner, ..SimConfig::default() }
}

/// Table 2 — maximum supported qubits per simulator under a fixed memory
/// budget. `budget` scales the paper's 128 GB machine; dense simulators
/// need the full `2^(n+4)` bytes, BMQSIM needs only its compressed peak,
/// and BMQSIM+SSD adds the secondary tier.
pub fn table2_max_qubits(budget: usize, n_max: usize) -> Result<Table> {
    let mut t = Table::new(&["algorithm", "dense (SV-Sim class)", "bmqsim", "bmqsim+ssd"]);
    // Dense bound is analytic: largest n with 2^(n+4) <= budget.
    let dense_max = (0..=n_max)
        .filter(|&n| standard_memory_bytes(n, Precision::F64) <= budget as u128)
        .max()
        .unwrap_or(0);
    for name in generators::ALL {
        let probe = |use_ssd: bool| -> usize {
            let mut best = 0usize;
            for n in (10..=n_max).step_by(2) {
                let c = match generators::build(name, n, SEED) {
                    Ok(c) => c,
                    Err(_) => break,
                };
                let mut config = cfg(14, 2);
                config.memory_budget = Some(budget);
                config.spill_dir = use_ssd.then(spill_dir);
                match BmqSim::new(config).run(&c, false) {
                    Ok(_) => best = n,
                    Err(_) => break,
                }
            }
            best
        };
        let bm = probe(false);
        let ssd = probe(true);
        t.row(&[
            name.to_string(),
            dense_max.to_string(),
            bm.to_string(),
            ssd.to_string(),
        ]);
    }
    Ok(t)
}

/// Fig. 7 — simulation time: SC19-Sim (CPU), SC19-Sim (GPU analogue), and
/// BMQSIM. Returns the timing table (speedups in the last columns).
pub fn fig07_sc19_compare(algos: &[&str], ns: &[usize]) -> Result<Table> {
    let mut t = Table::new(&[
        "algorithm", "n", "sc19-cpu (s)", "sc19-gpu (s)", "bmqsim (s)", "speedup vs cpu",
        "speedup vs gpu",
    ]);
    for &name in algos {
        for &n in ns {
            let c = generators::build(name, n, SEED)?;
            let config = cfg(n.saturating_sub(4).max(4), 2);
            let sc_cpu = Sc19Sim::new(config.clone(), 1).run(&c, false)?;
            let sc_gpu = Sc19Sim::new(config.clone(), 4).run(&c, false)?;
            let bm = BmqSim::new(config).run(&c, false)?;
            t.row(&[
                name.to_string(),
                n.to_string(),
                format!("{:.3}", sc_cpu.wall_secs),
                format!("{:.3}", sc_gpu.wall_secs),
                format!("{:.3}", bm.wall_secs),
                format!("{:.1}x", sc_cpu.wall_secs / bm.wall_secs),
                format!("{:.1}x", sc_gpu.wall_secs / bm.wall_secs),
            ]);
        }
    }
    Ok(t)
}

/// Fig. 8 — fidelity: SC19-Sim vs BMQSIM against the dense ideal state.
pub fn fig08_fidelity(algos: &[&str], ns: &[usize]) -> Result<Table> {
    let mut t = Table::new(&["algorithm", "n", "sc19 fidelity", "bmqsim fidelity"]);
    for &name in algos {
        for &n in ns {
            let c = generators::build(name, n, SEED)?;
            let ideal = DenseSim::new(SimConfig::default()).run(&c)?.state.unwrap();
            let config = cfg(n.saturating_sub(4).max(4), 2);
            let sc = Sc19Sim::new(config.clone(), 1).run(&c, true)?;
            let bm = BmqSim::new(config).run(&c, true)?;
            t.row(&[
                name.to_string(),
                n.to_string(),
                format!("{:.6}", sc.state.as_ref().unwrap().fidelity(&ideal)),
                format!("{:.6}", bm.state.as_ref().unwrap().fidelity(&ideal)),
            ]);
        }
    }
    Ok(t)
}

/// Fig. 8 frontier — adaptive error control on the deep-random workload.
///
/// Three runs of the same circuit against the dense ideal:
///
/// 1. **fixed** — the *equivalent fixed global bound* `ε_total/(S+1)`
///    (with `ε_total = (1-target)/2` over `S` stages + init): the bound a
///    target-naive run must hard-pin to guarantee the target, with no
///    refunds and no per-block shaping.
/// 2. **global** — the budget controller with [`ErrorPolicy::Global`].
/// 3. **amplitude** — the controller with [`ErrorPolicy::Amplitude`].
///
/// Returns the printable table plus the machine-readable fields for
/// `BENCH_frontier.json`. `bench_check` gates `compression_ratio_at_target`
/// (the amplitude run's whole-run ratio) and `fidelity_margin`
/// (`(fidelity - target)/(1 - target)`, i.e. 0 at the target and 1 at
/// ideal — it must stay well above 0).
///
/// [`ErrorPolicy::Global`]: crate::compress::budget::ErrorPolicy::Global
/// [`ErrorPolicy::Amplitude`]: crate::compress::budget::ErrorPolicy::Amplitude
pub fn fig08_frontier(
    n: usize,
    block_qubits: usize,
    target: f64,
) -> Result<(Table, Vec<(String, String)>)> {
    use crate::compress::budget::ErrorPolicy;
    let c = generators::build("random", n, SEED)?;
    let ideal = DenseSim::new(SimConfig::default()).run(&c)?.state.unwrap();
    // The stage count this workload partitions into at this geometry —
    // the S of the naive equivalent bound.
    let plan =
        crate::circuit::partition_circuit(&c, block_qubits.min(n), 2)?;
    let stages = plan.stages.len();
    let eps_total = (1.0 - target) / 2.0;
    let fixed_bound = eps_total / (stages + 1) as f64;

    let run = |ft: Option<f64>, policy: ErrorPolicy, pin: Option<f64>| -> Result<SimResult> {
        let mut config = cfg(block_qubits, 2);
        if let Some(b) = pin {
            // Same codec kind/prescan as the budget runs' base codec;
            // only the bound is pinned.
            config.codec = config.codec.with_bound(b);
        }
        config.fidelity_target = ft;
        config.error_policy = policy;
        BmqSim::new(config).run(&c, true)
    };
    let fixed = run(None, ErrorPolicy::Global, Some(fixed_bound))?;
    let global = run(Some(target), ErrorPolicy::Global, None)?;
    let amp = run(Some(target), ErrorPolicy::Amplitude, None)?;

    let fid = |r: &SimResult| r.state.as_ref().unwrap().fidelity(&ideal);
    let (f_fixed, f_global, f_amp) = (fid(&fixed), fid(&global), fid(&amp));
    let ratio = |r: &SimResult| r.metrics.compression_ratio();
    let (r_fixed, r_global, r_amp) = (ratio(&fixed), ratio(&global), ratio(&amp));

    let mut t = Table::new(&[
        "config", "fidelity", "margin", "comp. ratio", "budget spent", "bounds [min, max]",
        "recompressions",
    ]);
    for (label, r, f) in
        [("fixed bound", &fixed, f_fixed), ("global", &global, f_global), ("amplitude", &amp, f_amp)]
    {
        t.row(&[
            label.to_string(),
            format!("{f:.7}"),
            format!("{:+.2e}", f - target),
            format!("{:.2}x", r.metrics.compression_ratio()),
            format!("{:.2e}", r.metrics.error_budget_spent),
            format!(
                "[{:.1e}, {:.1e}]",
                r.metrics.per_block_bound_min, r.metrics.per_block_bound_max
            ),
            r.metrics.recompressions.to_string(),
        ]);
    }
    let fields = vec![
        ("n".to_string(), n.to_string()),
        ("block_qubits".to_string(), block_qubits.to_string()),
        ("stages".to_string(), stages.to_string()),
        ("fidelity_target".to_string(), bench_json::num(target)),
        ("equivalent_fixed_bound".to_string(), format!("{fixed_bound:e}")),
        // Gated: the amplitude policy's whole-run compression ratio at the
        // target, and its normalized fidelity margin above the target.
        ("compression_ratio_at_target".to_string(), bench_json::num(r_amp)),
        (
            "fidelity_margin".to_string(),
            bench_json::num((f_amp - target) / (1.0 - target)),
        ),
        // The headline comparison (informational): ratio gain over the
        // equivalent fixed bound at no fidelity deficit.
        ("ratio_gain_vs_fixed".to_string(), bench_json::num(r_amp / r_fixed)),
        ("ratio_gain_global_vs_fixed".to_string(), bench_json::num(r_global / r_fixed)),
        ("fixed_fidelity".to_string(), format!("{f_fixed:.9}")),
        ("global_fidelity".to_string(), format!("{f_global:.9}")),
        ("amplitude_fidelity".to_string(), format!("{f_amp:.9}")),
        ("fixed_ratio".to_string(), bench_json::num(r_fixed)),
        ("global_ratio".to_string(), bench_json::num(r_global)),
        (
            "amplitude_budget_spent".to_string(),
            format!("{:e}", amp.metrics.error_budget_spent),
        ),
        (
            "amplitude_bound_min".to_string(),
            format!("{:e}", amp.metrics.per_block_bound_min),
        ),
        (
            "amplitude_bound_max".to_string(),
            format!("{:e}", amp.metrics.per_block_bound_max),
        ),
        ("recompressions".to_string(), amp.metrics.recompressions.to_string()),
    ];
    Ok((t, fields))
}

/// Fig. 9 — memory consumption vs the standard `2^(n+4)` bytes, plus §5.4
/// spill behaviour under a restricted budget (the X1 row set).
pub fn fig09_memory(algos: &[&str], ns: &[usize], restricted_budget: usize) -> Result<(Table, Table)> {
    let mut t = Table::new(&["algorithm", "n", "standard", "bmqsim peak", "reduction"]);
    let mut spill = Table::new(&["algorithm", "n", "budget", "spill events", "% blocks on ssd"]);
    for &name in algos {
        for &n in ns {
            let c = generators::build(name, n, SEED)?;
            let config = cfg(14, 2);
            let r = BmqSim::new(config).run(&c, false)?;
            let std_bytes = standard_memory_bytes(n, Precision::F64);
            t.row(&[
                name.to_string(),
                n.to_string(),
                fmt_bytes(std_bytes),
                fmt_bytes(r.peak_bytes as u128),
                format!("{:.2}x", std_bytes as f64 / r.peak_bytes as f64),
            ]);
            // Restricted-budget rerun: forces the two-level manager to
            // engage (paper limits Machine 1 to 8 GB; we scale down).
            let mut config = cfg(14, 2);
            config.memory_budget = Some(restricted_budget);
            config.spill_dir = Some(spill_dir());
            let r = BmqSim::new(config).run(&c, false)?;
            spill.row(&[
                name.to_string(),
                n.to_string(),
                fmt_bytes(restricted_budget as u128),
                r.mem.spill_events.to_string(),
                format!("{:.0}%", 100.0 * r.mem.secondary_fraction()),
            ]);
        }
    }
    Ok((t, spill))
}

/// Fig. 9 addendum — the §4.4 *concurrency* study: single-shard
/// synchronous-spill baseline vs the sharded + async-writer + prefetching
/// store, under a budget squeezed to force a heavy spill fraction with
/// `streams > 1` concurrent group chains. Returns the printable table plus
/// machine-readable fields for `BENCH_memory.json` (spill fraction,
/// prefetch hit rate, spill stall time, group-chain throughput).
pub fn fig09_async_spill(
    name: &str,
    n: usize,
    block_qubits: usize,
    streams: usize,
) -> Result<(Table, Vec<(String, String)>)> {
    let c = generators::build(name, n, SEED)?;
    let mk = |budget: Option<usize>, shards: usize, sync: bool, depth: usize| {
        let mut config = cfg(block_qubits, 2);
        config.pipeline = PipelineConfig::new(1, streams);
        config.memory_budget = budget;
        if budget.is_some() {
            config.spill_dir = Some(spill_dir());
        }
        config.store_shards = shards;
        config.sync_spill = sync;
        config.prefetch_depth = depth;
        config
    };
    // Probe the unconstrained compressed peak, then squeeze the budget to
    // a quarter of it: >=30% of blocks must live on the secondary tier.
    let probe = BmqSim::new(mk(None, 8, false, 0)).run(&c, false)?;
    let budget = (probe.peak_bytes / 4).max(1 << 12);
    let sync_r = BmqSim::new(mk(Some(budget), 1, true, 0)).run(&c, true)?;
    let async_r = BmqSim::new(mk(Some(budget), 8, false, 4)).run(&c, true)?;
    let fidelity = async_r
        .state
        .as_ref()
        .unwrap()
        .fidelity_normalized(sync_r.state.as_ref().unwrap());
    let sync_thr = sync_r.metrics.groups_processed as f64 / sync_r.wall_secs;
    let async_thr = async_r.metrics.groups_processed as f64 / async_r.wall_secs;

    let mut t = Table::new(&[
        "store", "wall (s)", "groups/s", "spill %", "evictions", "prefetch h/m",
        "stall (ms)",
    ]);
    for (label, r, thr) in
        [("1-shard sync", &sync_r, sync_thr), ("sharded async", &async_r, async_thr)]
    {
        t.row(&[
            label.to_string(),
            format!("{:.3}", r.wall_secs),
            format!("{thr:.0}"),
            format!("{:.0}%", 100.0 * r.mem.secondary_fraction()),
            r.mem.evictions.to_string(),
            format!("{}/{}", r.mem.prefetch_hits, r.mem.prefetch_misses),
            format!("{:.1}", r.mem.spill_stall_ns as f64 * 1e-6),
        ]);
    }
    let fields = vec![
        ("algo".to_string(), format!("\"{name}\"")),
        ("n".to_string(), n.to_string()),
        ("workers".to_string(), streams.to_string()),
        ("budget_bytes".to_string(), budget.to_string()),
        ("unconstrained_peak_bytes".to_string(), probe.peak_bytes.to_string()),
        ("sync_wall_s".to_string(), bench_json::num(sync_r.wall_secs)),
        ("async_wall_s".to_string(), bench_json::num(async_r.wall_secs)),
        ("speedup".to_string(), bench_json::num(sync_r.wall_secs / async_r.wall_secs)),
        ("sync_groups_per_s".to_string(), bench_json::num(sync_thr)),
        ("async_groups_per_s".to_string(), bench_json::num(async_thr)),
        (
            "spill_fraction".to_string(),
            bench_json::num(async_r.mem.secondary_fraction()),
        ),
        ("evictions".to_string(), async_r.mem.evictions.to_string()),
        ("prefetch_hits".to_string(), async_r.mem.prefetch_hits.to_string()),
        ("prefetch_misses".to_string(), async_r.mem.prefetch_misses.to_string()),
        (
            "prefetch_hit_rate".to_string(),
            bench_json::num(async_r.mem.prefetch_hit_rate()),
        ),
        (
            "sync_spill_stall_ms".to_string(),
            bench_json::num(sync_r.mem.spill_stall_ns as f64 * 1e-6),
        ),
        (
            "async_spill_stall_ms".to_string(),
            bench_json::num(async_r.mem.spill_stall_ns as f64 * 1e-6),
        ),
        ("peak_bytes_sync".to_string(), sync_r.peak_bytes.to_string()),
        ("peak_bytes_async".to_string(), async_r.peak_bytes.to_string()),
        ("fidelity_async_vs_sync".to_string(), bench_json::num(fidelity)),
    ];
    Ok((t, fields))
}

/// Overhead-concealment study (Fig. 11 addendum / ISSUE 4 acceptance):
/// sequential vs software-pipelined (decode → apply → encode overlapped)
/// group chains under a budget squeezed to a quarter of the compressed
/// peak, `workers` concurrent chains. The pipelined run must be
/// *byte-identical* in its terminal state while concealing codec/transfer
/// time behind gate application. Returns the printable table plus the
/// machine-readable fields for `BENCH_overlap.json` (throughput, speedup,
/// occupancy, stall breakdown, fidelity, bitwise-equality flag).
pub fn overlap_study(
    name: &str,
    n: usize,
    block_qubits: usize,
    workers: usize,
    depth: usize,
) -> Result<(Table, Vec<(String, String)>)> {
    let c = generators::build(name, n, SEED)?;
    let mk = |budget: Option<usize>, overlap: bool, cross: bool| {
        let mut config = cfg(block_qubits, 2);
        config.pipeline = PipelineConfig::new(1, workers);
        config.memory_budget = budget;
        if budget.is_some() {
            config.spill_dir = Some(spill_dir());
        }
        config.overlap = OverlapMode::pinned(overlap);
        config.cross_stage = OverlapMode::pinned(cross);
        config.pipeline_depth = depth;
        config.pipeline_depth_auto = false; // the study pins its geometry
        config
    };
    // Probe the unconstrained compressed peak, then squeeze the budget to
    // a quarter of it so the spill machinery is fully engaged.
    let probe = BmqSim::new(mk(None, false, false)).run(&c, false)?;
    let budget = (probe.peak_bytes / 4).max(1 << 12);
    let seq = BmqSim::new(mk(Some(budget), false, false)).run(&c, true)?;
    // Pipelined with the per-stage barrier, then with cross-stage epochs:
    // the boundary cost the stitched schedule + shared-block gates remove.
    let ovl = BmqSim::new(mk(Some(budget), true, false)).run(&c, true)?;
    let xst = BmqSim::new(mk(Some(budget), true, true)).run(&c, true)?;

    let sa = seq.state.as_ref().unwrap();
    let oa = ovl.state.as_ref().unwrap();
    let xa = xst.state.as_ref().unwrap();
    let bitwise = sa.re == oa.re && sa.im == oa.im && sa.re == xa.re && sa.im == xa.im;
    let fidelity = oa.fidelity_normalized(sa);
    let seq_thr = seq.metrics.groups_processed as f64 / seq.wall_secs;
    let ovl_thr = ovl.metrics.groups_processed as f64 / ovl.wall_secs;
    let xst_thr = xst.metrics.groups_processed as f64 / xst.wall_secs;
    let occ = |r: &crate::sim::SimResult| {
        r.metrics
            .pipeline_occupancy()
            .map_or("-".to_string(), |v| format!("{:.0}%", 100.0 * v))
    };
    let occ_json = |r: &crate::sim::SimResult| {
        r.metrics.pipeline_occupancy().map_or("null".to_string(), bench_json::num)
    };

    let mut t = Table::new(&[
        "chain", "wall (s)", "groups/s", "occupancy", "decode-ahead", "overlap stall (ms)",
        "boundary stall (ms)", "spill stall (ms)", "reordered",
    ]);
    for (label, r, thr) in [
        ("sequential", &seq, seq_thr),
        ("pipelined", &ovl, ovl_thr),
        ("cross-stage", &xst, xst_thr),
    ] {
        t.row(&[
            label.to_string(),
            format!("{:.3}", r.wall_secs),
            format!("{thr:.0}"),
            occ(r),
            r.metrics.decode_ahead_hits.to_string(),
            format!("{:.1}", r.metrics.overlap_stall_ns as f64 * 1e-6),
            format!("{:.1}", r.metrics.boundary_stall_ns as f64 * 1e-6),
            format!("{:.1}", r.mem.spill_stall_ns as f64 * 1e-6),
            r.metrics.groups_reordered.to_string(),
        ]);
    }
    let fields = vec![
        ("algo".to_string(), format!("\"{name}\"")),
        ("n".to_string(), n.to_string()),
        ("workers".to_string(), workers.to_string()),
        ("pipeline_depth".to_string(), depth.to_string()),
        ("budget_bytes".to_string(), budget.to_string()),
        ("unconstrained_peak_bytes".to_string(), probe.peak_bytes.to_string()),
        ("seq_wall_s".to_string(), bench_json::num(seq.wall_secs)),
        ("pipelined_wall_s".to_string(), bench_json::num(ovl.wall_secs)),
        ("cross_stage_wall_s".to_string(), bench_json::num(xst.wall_secs)),
        ("seq_groups_per_s".to_string(), bench_json::num(seq_thr)),
        ("pipelined_groups_per_s".to_string(), bench_json::num(ovl_thr)),
        ("cross_stage_groups_per_s".to_string(), bench_json::num(xst_thr)),
        ("speedup".to_string(), bench_json::num(ovl_thr / seq_thr)),
        ("cross_stage_speedup".to_string(), bench_json::num(xst_thr / seq_thr)),
        // `pipeline_occupancy` stays the barrier-pipelined run for baseline
        // continuity; `cross_stage_occupancy` is the headline the epoch
        // window is expected to raise.
        ("pipeline_occupancy".to_string(), occ_json(&ovl)),
        ("cross_stage_occupancy".to_string(), occ_json(&xst)),
        (
            "cross_stage_decodes".to_string(),
            xst.metrics.cross_stage_decodes.to_string(),
        ),
        (
            "boundary_stall_ms".to_string(),
            bench_json::num(xst.metrics.boundary_stall_ns as f64 * 1e-6),
        ),
        (
            "epoch_drain_ms".to_string(),
            bench_json::num(xst.metrics.epoch_drain_ns as f64 * 1e-6),
        ),
        (
            "decode_ahead_hits".to_string(),
            ovl.metrics.decode_ahead_hits.to_string(),
        ),
        (
            "overlap_stall_ms".to_string(),
            bench_json::num(ovl.metrics.overlap_stall_ns as f64 * 1e-6),
        ),
        (
            "seq_spill_stall_ms".to_string(),
            bench_json::num(seq.mem.spill_stall_ns as f64 * 1e-6),
        ),
        (
            "pipelined_spill_stall_ms".to_string(),
            bench_json::num(ovl.mem.spill_stall_ns as f64 * 1e-6),
        ),
        ("groups_reordered".to_string(), ovl.metrics.groups_reordered.to_string()),
        ("prefetch_depth_final".to_string(), ovl.mem.prefetch_depth.to_string()),
        // Persistent-pool churn accounting: threads spawned ONCE for the
        // run (3 × workers) vs the stage handoffs that each used to cost a
        // spawn/join of all of them.
        (
            "phase_threads_spawned".to_string(),
            ovl.metrics.phase_threads_spawned.to_string(),
        ),
        (
            "pool_stage_handoffs".to_string(),
            ovl.metrics.pool_stage_handoffs.to_string(),
        ),
        ("ring_depth_final".to_string(), ovl.metrics.ring_depth_final.to_string()),
        ("state_bitwise_equal".to_string(), bitwise.to_string()),
        ("fidelity_pipelined_vs_seq".to_string(), bench_json::num(fidelity)),
    ];
    Ok((t, fields))
}

/// Fig. 11 addendum — the overlap **auto-enable crossover**: sweep the
/// block size (and with it the group size) at fixed `n`, and for each
/// geometry run pinned-sequential, pinned-overlapped, and auto. The table
/// shows where the measured overlap win crosses break-even and which side
/// the heuristic picked; the JSON feeds the calibration of
/// [`crate::sim::OVERLAP_AUTO_MIN_CONCEAL_NS`].
pub fn fig11_auto_enable(
    name: &str,
    n: usize,
    blocks: &[usize],
) -> Result<(Table, Vec<(String, String)>)> {
    let c = generators::build(name, n, SEED)?;
    let mut t = Table::new(&[
        "block_qubits", "auto on/off stages", "seq groups/s", "overlap groups/s",
        "overlap speedup", "auto groups/s",
    ]);
    let mut fields: Vec<(String, String)> = vec![
        ("algo".to_string(), format!("\"{name}\"")),
        ("n".to_string(), n.to_string()),
    ];
    for &b in blocks {
        let mk = |mode: OverlapMode| {
            let mut config = cfg(b, 2);
            config.pipeline = PipelineConfig::new(1, 2);
            config.overlap = mode;
            config.pipeline_depth = 2;
            config.pipeline_depth_auto = false;
            config
        };
        let seq = BmqSim::new(mk(OverlapMode::Off)).run(&c, false)?;
        let ovl = BmqSim::new(mk(OverlapMode::On)).run(&c, false)?;
        let auto_r = BmqSim::new(mk(OverlapMode::Auto)).run(&c, false)?;
        let thr = |r: &SimResult| r.metrics.groups_processed as f64 / r.wall_secs;
        t.row(&[
            b.to_string(),
            format!(
                "{}/{}",
                auto_r.metrics.auto_overlap_on, auto_r.metrics.auto_overlap_off
            ),
            format!("{:.0}", thr(&seq)),
            format!("{:.0}", thr(&ovl)),
            format!("{:.2}x", thr(&ovl) / thr(&seq)),
            format!("{:.0}", thr(&auto_r)),
        ]);
        fields.push((
            format!("b{b}"),
            bench_json::obj(&[
                ("auto_on_stages".to_string(), auto_r.metrics.auto_overlap_on.to_string()),
                (
                    "auto_off_stages".to_string(),
                    auto_r.metrics.auto_overlap_off.to_string(),
                ),
                ("seq_groups_per_s".to_string(), bench_json::num(thr(&seq))),
                ("overlap_groups_per_s".to_string(), bench_json::num(thr(&ovl))),
                ("overlap_speedup".to_string(), bench_json::num(thr(&ovl) / thr(&seq))),
                ("auto_groups_per_s".to_string(), bench_json::num(thr(&auto_r))),
            ]),
        ));
    }
    Ok((t, fields))
}

/// Fig. 10 — simulation time vs the dense baseline across circuits/sizes.
pub fn fig10_simtime(algos: &[&str], ns: &[usize]) -> Result<Table> {
    let mut t = Table::new(&["algorithm", "n", "dense (s)", "bmqsim (s)", "bmqsim/dense"]);
    for &name in algos {
        for &n in ns {
            let c = generators::build(name, n, SEED)?;
            let dense = DenseSim::new(SimConfig::default()).run(&c)?;
            let bm = BmqSim::new(cfg(14, 2)).run(&c, false)?;
            t.row(&[
                name.to_string(),
                n.to_string(),
                format!("{:.3}", dense.wall_secs),
                format!("{:.3}", bm.wall_secs),
                format!("{:.2}x", bm.wall_secs / dense.wall_secs),
            ]);
        }
    }
    Ok(t)
}

/// Fig. 11 — compression overhead: BMQSIM vs BMQSIM-without-compression.
pub fn fig11_comp_overhead(algos: &[&str], ns: &[usize]) -> Result<Table> {
    let mut t = Table::new(&[
        "algorithm", "n", "no-compress (s)", "compress (s)", "overhead", "ratio",
    ]);
    for &name in algos {
        for &n in ns {
            let c = generators::build(name, n, SEED)?;
            let mut raw_cfg = cfg(14, 2);
            raw_cfg.codec = Codec::raw();
            let raw = BmqSim::new(raw_cfg).run(&c, false)?;
            let comp = BmqSim::new(cfg(14, 2)).run(&c, false)?;
            t.row(&[
                name.to_string(),
                n.to_string(),
                format!("{:.3}", raw.wall_secs),
                format!("{:.3}", comp.wall_secs),
                format!("{:+.1}%", 100.0 * (comp.wall_secs - raw.wall_secs) / raw.wall_secs),
                format!("{:.1}x", comp.metrics.compression_ratio()),
            ]);
        }
    }
    Ok(t)
}

/// Fig. 12 — pipeline stream-count sweep (1/2/4/8) at fixed geometry.
/// `overlap` additionally runs each stream's chain on the three-phase
/// decode/apply/encode pipeline (depth 2), the §4.2 overhead-concealment
/// knob layered on top of the stream count.
pub fn fig12_streams(algos: &[&str], n: usize, overlap: bool) -> Result<Table> {
    Ok(fig12_walls(algos, n, overlap)?.0)
}

/// The fig12 sweep returning both the printable table and the raw wall
/// times, keyed `"{algo}_s{streams}"`.
fn fig12_walls(
    algos: &[&str],
    n: usize,
    overlap: bool,
) -> Result<(Table, Vec<(String, f64)>)> {
    let label = if overlap { "streams=1 (s, overlapped)" } else { "streams=1 (s)" };
    let mut t = Table::new(&["algorithm", label, "2", "4", "8"]);
    let mut walls: Vec<(String, f64)> = Vec::new();
    for &name in algos {
        let c = generators::build(name, n, SEED)?;
        let mut cells = vec![name.to_string()];
        for streams in [1usize, 2, 4, 8] {
            let mut config = cfg(n.saturating_sub(6).max(4), 2);
            config.pipeline = PipelineConfig::new(1, streams);
            config.overlap = OverlapMode::pinned(overlap);
            config.pipeline_depth_auto = false;
            let r = BmqSim::new(config).run(&c, false)?;
            cells.push(format!("{:.3}", r.wall_secs));
            walls.push((format!("{name}_s{streams}"), r.wall_secs));
        }
        t.row(&cells);
    }
    Ok((t, walls))
}

/// Fig. 12 study for `BENCH_streams.json`: the stream sweep in both chain
/// modes, plus the per-PR trajectory fields — every wall time and the
/// geometric-mean overlapped-vs-sequential speedup at each stream count.
pub fn fig12_streams_study(
    algos: &[&str],
    n: usize,
) -> Result<(Vec<Table>, Vec<(String, String)>)> {
    let (seq_t, seq_w) = fig12_walls(algos, n, false)?;
    let (ovl_t, ovl_w) = fig12_walls(algos, n, true)?;
    let mut fields: Vec<(String, String)> = vec![
        ("bench".to_string(), "\"fig12_streams\"".to_string()),
        ("n".to_string(), n.to_string()),
    ];
    for (key, wall) in &seq_w {
        fields.push((format!("{key}_wall_s"), bench_json::num(*wall)));
    }
    for (key, wall) in &ovl_w {
        fields.push((format!("{key}_overlap_wall_s"), bench_json::num(*wall)));
    }
    for streams in [1usize, 2, 4, 8] {
        let suffix = format!("_s{streams}");
        let mut log_sum = 0.0f64;
        let mut count = 0usize;
        for ((sk, sw), (ok_, ow)) in seq_w.iter().zip(ovl_w.iter()) {
            debug_assert_eq!(sk, ok_);
            if sk.ends_with(&suffix) && *sw > 0.0 && *ow > 0.0 {
                log_sum += (sw / ow).ln();
                count += 1;
            }
        }
        let geomean = if count > 0 { (log_sum / count as f64).exp() } else { f64::NAN };
        fields.push((
            format!("overlap_speedup_geomean_s{streams}"),
            bench_json::num(geomean),
        ));
    }
    Ok((vec![seq_t, ovl_t], fields))
}

/// Fig. 13 — multi-device scaling (1/2/4 logical devices).
pub fn fig13_scaling(algos: &[&str], n: usize) -> Result<Table> {
    let mut t = Table::new(&["algorithm", "1 device (s)", "2 (s)", "4 (s)", "speedup@4"]);
    for &name in algos {
        let c = generators::build(name, n, SEED)?;
        let mut secs = Vec::new();
        for devices in [1usize, 2, 4] {
            let mut config = cfg(n.saturating_sub(6).max(4), 2);
            config.pipeline = PipelineConfig::new(devices, 2);
            let r = BmqSim::new(config).run(&c, false)?;
            secs.push(r.wall_secs);
        }
        t.row(&[
            name.to_string(),
            format!("{:.3}", secs[0]),
            format!("{:.3}", secs[1]),
            format!("{:.3}", secs[2]),
            format!("{:.2}x", secs[0] / secs[2]),
        ]);
    }
    Ok(t)
}

/// Fig. 14 — partition time as a fraction of end-to-end simulation time.
pub fn fig14_partition_overhead(algos: &[&str], n: usize) -> Result<Table> {
    let mut t = Table::new(&["algorithm", "partition (ms)", "total (s)", "fraction"]);
    for &name in algos {
        let c = generators::build(name, n, SEED)?;
        let r = BmqSim::new(cfg(14, 2)).run(&c, false)?;
        let part = r.metrics.phase("partition");
        t.row(&[
            name.to_string(),
            format!("{:.3}", part * 1e3),
            format!("{:.3}", r.wall_secs),
            format!("{:.4}%", 100.0 * part / r.wall_secs),
        ]);
    }
    Ok(t)
}

/// Fig. 15 — inner-size x block-size sweep: compression ratio (standard /
/// practical peak) and simulation time.
pub fn fig15_params(name: &str, n: usize, inners: &[usize], blocks: &[usize]) -> Result<(Table, Table)> {
    let mut ratio = Table::new(
        &std::iter::once("inner \\ block".to_string())
            .chain(blocks.iter().map(|b| format!("b={b}")))
            .collect::<Vec<_>>()
            .iter()
            .map(|s| s.as_str())
            .collect::<Vec<_>>(),
    );
    let mut time = Table::new(
        &std::iter::once("inner \\ block".to_string())
            .chain(blocks.iter().map(|b| format!("b={b}")))
            .collect::<Vec<_>>()
            .iter()
            .map(|s| s.as_str())
            .collect::<Vec<_>>(),
    );
    let c = generators::build(name, n, SEED)?;
    let std_bytes = standard_memory_bytes(n, Precision::F64) as f64;
    for &inner in inners {
        let mut rrow = vec![inner.to_string()];
        let mut trow = vec![inner.to_string()];
        for &b in blocks {
            let r = BmqSim::new(cfg(b, inner)).run(&c, false)?;
            rrow.push(format!("{:.1}x", std_bytes / r.peak_bytes as f64));
            trow.push(format!("{:.3}s", r.wall_secs));
        }
        ratio.row(&rrow);
        time.row(&trow);
    }
    Ok((ratio, time))
}

/// Ablation A1 — bitmap pre-scan on/off: compressed size + time on
/// amplitude-like synthetic planes.
pub fn ablation_prescan(plane_len: usize) -> Result<Table> {
    let mut t = Table::new(&["plane", "prescan bytes", "no-prescan bytes", "gain"]);
    let mut rng = SplitMix64::new(SEED);
    let planes: Vec<(&str, Vec<f64>)> = vec![
        ("sparse (ghz-like)", {
            let mut v = vec![0.0f64; plane_len];
            v[0] = std::f64::consts::FRAC_1_SQRT_2;
            v[plane_len - 1] = -std::f64::consts::FRAC_1_SQRT_2;
            v
        }),
        ("uniform-phase", {
            let a = (1.0 / plane_len as f64).sqrt();
            (0..plane_len).map(|i| if i % 2 == 0 { a } else { -a }).collect()
        }),
        ("gaussian", (0..plane_len).map(|_| rng.next_gaussian() * 1e-3).collect()),
        ("sign-clustered", {
            (0..plane_len)
                .map(|i| {
                    let mag = 1e-2 * (1.0 + 0.1 * rng.next_f64());
                    if (i / 1000) % 2 == 0 {
                        mag
                    } else {
                        -mag
                    }
                })
                .collect()
        }),
    ];
    for (name, plane) in &planes {
        let with = Codec { kind: CodecKind::PointwiseRel, error_bound: 1e-3, prescan: true }
            .compress(plane)?;
        let without = Codec { kind: CodecKind::PointwiseRel, error_bound: 1e-3, prescan: false }
            .compress(plane)?;
        t.row(&[
            name.to_string(),
            with.len().to_string(),
            without.len().to_string(),
            format!("{:.2}x", without.len() as f64 / with.len() as f64),
        ]);
    }
    Ok(t)
}

/// Ablation A2 — error-control mode: point-wise relative (BMQSIM) vs plain
/// absolute bound at matched nominal bounds: fidelity + ratio.
pub fn ablation_error_mode(name: &str, n: usize) -> Result<Table> {
    let mut t = Table::new(&["codec", "bound", "fidelity", "peak bytes", "reduction"]);
    let c = generators::build(name, n, SEED)?;
    let ideal = DenseSim::new(SimConfig::default()).run(&c)?.state.unwrap();
    let std_bytes = standard_memory_bytes(n, Precision::F64) as f64;
    for (label, codec) in [
        ("pointwise-rel", Codec::pointwise(1e-3)),
        ("pointwise-rel", Codec::pointwise(1e-2)),
        ("absolute", Codec::absolute(1e-3)),
        ("absolute", Codec::absolute(1e-2)),
    ] {
        let mut config = cfg(n.saturating_sub(6).max(4), 2);
        config.codec = codec;
        let r = BmqSim::new(config).run(&c, true)?;
        t.row(&[
            label.to_string(),
            format!("{:.0e}", codec.error_bound),
            format!("{:.6}", r.state.as_ref().unwrap().fidelity_normalized(&ideal)),
            r.peak_bytes.to_string(),
            format!("{:.1}x", std_bytes / r.peak_bytes as f64),
        ]);
    }
    Ok(t)
}

/// Timing helper for bench mains: run `f`, print the table with a header.
pub fn print_experiment(title: &str, f: impl FnOnce() -> Result<Vec<Table>>) {
    println!("\n=== {title} ===");
    let t0 = Instant::now();
    match f() {
        Ok(tables) => {
            for t in tables {
                println!("{t}");
            }
            println!("[{} took {:.1}s]", title, t0.elapsed().as_secs_f64());
        }
        Err(e) => println!("EXPERIMENT FAILED: {e}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig11_runs_at_tiny_scale() {
        let t = fig11_comp_overhead(&["ghz_state"], &[10]).unwrap();
        assert!(t.to_string().contains("ghz_state"));
    }

    #[test]
    fn overlap_study_is_byte_identical_at_tiny_scale() {
        let (t, fields) = overlap_study("qaoa", 10, 6, 2, 2).unwrap();
        let s = t.to_string();
        assert!(s.contains("sequential") && s.contains("pipelined"));
        assert!(s.contains("cross-stage"));
        let get = |k: &str| {
            fields
                .iter()
                .find(|(key, _)| key.as_str() == k)
                .map(|(_, v)| v.clone())
                .unwrap_or_else(|| panic!("missing field {k}"))
        };
        assert_eq!(get("state_bitwise_equal"), "true");
        assert_eq!(get("workers"), "2");
        assert!(get("speedup").parse::<f64>().unwrap() > 0.0);
        let occ = get("pipeline_occupancy").parse::<f64>().unwrap();
        assert!(occ > 0.0 && occ <= 1.0);
        let xocc = get("cross_stage_occupancy").parse::<f64>().unwrap();
        assert!(xocc > 0.0 && xocc <= 1.0);
        assert!(get("cross_stage_speedup").parse::<f64>().unwrap() > 0.0);
        get("boundary_stall_ms");
        get("epoch_drain_ms");
        get("cross_stage_decodes");
    }

    #[test]
    fn fig08_frontier_meets_target_at_tiny_scale() {
        let target = 0.999;
        let (t, fields) = fig08_frontier(9, 4, target).unwrap();
        let s = t.to_string();
        assert!(s.contains("fixed bound") && s.contains("amplitude"));
        let get = |k: &str| {
            fields
                .iter()
                .find(|(key, _)| key.as_str() == k)
                .map(|(_, v)| v.clone())
                .unwrap_or_else(|| panic!("missing field {k}"))
        };
        // The acceptance property, at tiny scale: both budget policies
        // land at or above the target…
        for k in ["global_fidelity", "amplitude_fidelity"] {
            let f = get(k).parse::<f64>().unwrap();
            assert!(f >= target, "{k} = {f} < {target}");
        }
        // …and the gated metrics are present and sane.
        assert!(get("compression_ratio_at_target").parse::<f64>().unwrap() >= 1.0);
        assert!(get("fidelity_margin").parse::<f64>().unwrap() > 0.0);
        assert!(get("ratio_gain_vs_fixed").parse::<f64>().unwrap() > 0.0);
    }

    #[test]
    fn fig12_overlap_variant_runs_at_tiny_scale() {
        let t = fig12_streams(&["ghz_state"], 10, true).unwrap();
        assert!(t.to_string().contains("overlapped"));
    }

    #[test]
    fn auto_enable_study_reports_decisions_at_tiny_scale() {
        let (t, fields) = fig11_auto_enable("ghz_state", 10, &[5]).unwrap();
        assert!(t.to_string().contains("overlap speedup"));
        let b5 = fields
            .iter()
            .find(|(k, _)| k == "b5")
            .map(|(_, v)| v.clone())
            .expect("missing b5 field");
        assert!(b5.contains("auto_on_stages") && b5.contains("overlap_speedup"));
    }

    #[test]
    fn bench_json_stamp_has_schema_and_sha() {
        // git_sha never panics and returns something non-empty.
        let sha = bench_json::git_sha();
        assert!(!sha.is_empty());
        assert!(bench_json::BENCH_SCHEMA_VERSION >= 2);
    }

    #[test]
    fn fig14_partition_fraction_is_small() {
        let t = fig14_partition_overhead(&["qft"], 12).unwrap();
        let s = t.to_string();
        assert!(s.contains("qft"));
    }

    #[test]
    fn ablation_prescan_shows_gain_on_clustered_signs() {
        let t = ablation_prescan(1 << 12).unwrap();
        assert!(t.to_string().contains("sign-clustered"));
    }

    #[test]
    fn table2_probe_small() {
        // 64 KiB budget: dense caps at n=12 (2^16 B); bmqsim should reach
        // higher on sparse circuits. Kept tiny — the real sweep lives in
        // `cargo bench --bench table2_max_qubits`.
        let t = table2_max_qubits(1 << 16, 14).unwrap();
        let s = t.to_string();
        assert!(s.contains("cat_state"));
    }
}
