//! Batched application of fused stage ops (tentpole of the fusion PR).
//!
//! [`apply_stage`] replaces the per-gate loop of the group chain: instead
//! of one full plane sweep per gate, the stage's [`FusedGate`] list is cut
//! into *sweep segments* and each segment costs ONE pass over the plane:
//!
//! * a maximal run of consecutive **tile-local** ops (every support bit
//!   below `tile_bits`) is applied tile-by-tile — the plane is walked in
//!   `2^tile_bits`-amplitude chunks and the whole run hits each chunk
//!   while it is hot in L2, so N local ops cost one sweep's worth of DRAM
//!   traffic instead of N;
//! * an op with a **high** support bit (`>= tile_bits`) falls back to a
//!   per-op sweep whose chunks are widened to close over its support.
//!
//! Ops are never reordered across segment boundaries, so the result is
//! bit-for-bit the sequential fused product regardless of tiling.
//!
//! Every sweep is parallelized over the pipeline's plane-chunk primitive
//! ([`run_plane_chunks`]): workers own disjoint, aligned index ranges —
//! no locking, and identical arithmetic per amplitude at every worker
//! count, so parallel sweeps are deterministic in the state.

use crate::circuit::fusion::FusedGate;
use crate::circuit::Gate;
use crate::gates::apply_gate_remapped;
use crate::pipeline::run_plane_chunks;

/// Default `log2(amplitudes)` per cache tile: `2^15` amplitudes are
/// 256 KiB per plane, 512 KiB for the re/im pair — sized for a ~1 MiB L2.
pub const DEFAULT_TILE_BITS: usize = 15;

/// What one [`apply_stage`] call did, for the `Metrics` counters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StageStats {
    /// Full passes over the plane (tiled runs count once).
    pub sweeps: u64,
    /// Fused-op kernel invocations over the whole plane.
    pub fused_ops_applied: u64,
}

/// One sweep segment: ops `[start, end)` applied in a single pass walked
/// in `2^chunk_bits`-amplitude chunks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Segment {
    pub start: usize,
    pub end: usize,
    pub chunk_bits: usize,
}

/// Cut a stage's fused ops into sweep segments for a `2^plane_bits`
/// plane. Identical for every SV group of a stage (all groups share the
/// plane geometry), so engines plan ONCE per stage and replay the plan
/// per group via [`apply_segments`] — no allocation in the group chain.
pub fn plan_segments(ops: &[FusedGate], plane_bits: usize, tile_bits: usize) -> Vec<Segment> {
    let tb = tile_bits.clamp(1, plane_bits.max(1));
    let mut segs = Vec::new();
    let mut i = 0;
    while i < ops.len() {
        if ops[i].max_bit() < tb {
            let start = i;
            while i < ops.len() && ops[i].max_bit() < tb {
                i += 1;
            }
            segs.push(Segment { start, end: i, chunk_bits: tb });
        } else {
            segs.push(Segment { start: i, end: i + 1, chunk_bits: ops[i].max_bit() + 1 });
            i += 1;
        }
    }
    segs
}

/// Plane sweeps a stage costs on a `2^plane_bits` plane — the per-stage
/// `Metrics::plane_sweeps` increment.
pub fn stage_sweeps(ops: &[FusedGate], plane_bits: usize, tile_bits: usize) -> u64 {
    plan_segments(ops, plane_bits, tile_bits).len() as u64
}

/// Apply a whole stage's fused ops in sweep-segmented, cache-blocked,
/// worker-parallel passes. `re`/`im` are the gathered group planes (any
/// power-of-two length covering every op's support). Convenience wrapper
/// that plans and applies in one call; hot loops that replay one stage
/// across many groups should plan once and use [`apply_segments`].
pub fn apply_stage(
    re: &mut [f64],
    im: &mut [f64],
    ops: &[FusedGate],
    tile_bits: usize,
    workers: usize,
) -> StageStats {
    let plane_bits = re.len().trailing_zeros() as usize;
    let segs = plan_segments(ops, plane_bits, tile_bits);
    apply_segments(re, im, ops, &segs, workers)
}

/// Execute a pre-planned sweep segmentation over one group plane.
pub fn apply_segments(
    re: &mut [f64],
    im: &mut [f64],
    ops: &[FusedGate],
    segs: &[Segment],
    workers: usize,
) -> StageStats {
    let len = re.len();
    debug_assert_eq!(len, im.len());
    debug_assert!(len.is_power_of_two());
    let plane_bits = len.trailing_zeros() as usize;
    let mut stats = StageStats { sweeps: 0, fused_ops_applied: 0 };
    for seg in segs {
        let run = &ops[seg.start..seg.end];
        let chunk_len = 1usize << seg.chunk_bits.min(plane_bits);
        run_plane_chunks(workers, chunk_len, re, im, |_base, rc, ic| {
            for op in run {
                apply_fused(rc, ic, op);
            }
        });
        stats.sweeps += 1;
        stats.fused_ops_applied += run.len() as u64;
    }
    stats
}

/// Apply one per-gate kernel as a worker-parallel plane sweep (the Sc19
/// path: per-gate semantics, parallel bandwidth). Chunks are sized to
/// close over the gate's highest target bit, at least `2^14` amplitudes
/// so per-chunk dispatch stays negligible.
pub fn apply_gate_parallel(
    re: &mut [f64],
    im: &mut [f64],
    gate: &Gate,
    bits: &[usize],
    workers: usize,
) {
    let len = re.len();
    debug_assert!(len.is_power_of_two() && len == im.len());
    let plane_bits = len.trailing_zeros() as usize;
    let max_bit = bits.iter().copied().max().unwrap_or(0);
    debug_assert!(max_bit < plane_bits);
    let chunk_bits = (max_bit + 1).max(14.min(plane_bits)).min(plane_bits);
    run_plane_chunks(workers, 1usize << chunk_bits, re, im, |_base, rc, ic| {
        apply_gate_remapped(rc, ic, gate, bits);
    });
}

/// Apply one fused op to a plane (or aligned sub-plane) that closes over
/// its support: `len >= 2^(max_bit + 1)`.
pub fn apply_fused(re: &mut [f64], im: &mut [f64], op: &FusedGate) {
    debug_assert_eq!(re.len(), im.len());
    debug_assert!(re.len().is_power_of_two());
    debug_assert!(re.len() >> op.max_bit() >= 2, "plane does not close over op support");
    match op.k() {
        1 => apply_fused_1q(re, im, op),
        _ => apply_fused_kq(re, im, op),
    }
}

/// Dense 1q fused kernel: the shared block-contiguous `dense_1q` loop
/// (`gates::dense_1q`), fed the fused 2x2 matrix.
fn apply_fused_1q(re: &mut [f64], im: &mut [f64], op: &FusedGate) {
    super::dense_1q(op.matrix(), re, im, 1usize << op.bits()[0]);
}

/// Generic k-qubit (k = 2, 3) fused kernel: gather `2^k` amplitudes per
/// site, dense mat-vec from a pre-flattened f64 matrix, scatter back.
fn apply_fused_kq(re: &mut [f64], im: &mut [f64], op: &FusedGate) {
    let len = re.len();
    let bits = op.bits();
    let k = op.k();
    let dim = 1usize << k;
    debug_assert!(dim <= 8);
    // Basis-pattern address offsets: site s lives at base | offs[s].
    let mut offs = [0usize; 8];
    for (s, off) in offs.iter_mut().enumerate().take(dim) {
        for (j, &b) in bits.iter().enumerate() {
            if s & (1 << j) != 0 {
                *off |= 1 << b;
            }
        }
    }
    let m = op.matrix();
    let mut mr = [[0f64; 8]; 8];
    let mut mi = [[0f64; 8]; 8];
    for r in 0..dim {
        for c in 0..dim {
            mr[r][c] = m[r * dim + c].re;
            mi[r][c] = m[r * dim + c].im;
        }
    }
    // Vector quad path: when the lowest support bit is >= 2, every run of 4
    // consecutive bases is memory-contiguous at every site offset (the low
    // 2 index bits sit below the whole support), so the lane-parallel quad
    // kernel applies. `subspace_bases` yields bases in ascending order and
    // `len >> k >= 4` whenever the plane closes over a support with
    // `bits[0] >= 2`, so stepping by 4 covers the plane exactly.
    let ops = crate::simd::dispatch();
    if ops.vectorized() && bits[0] >= 2 {
        ops.mark_used();
        let quad = ops.fused_kq_quad_fn();
        for base in subspace_bases(len, bits).step_by(4) {
            quad(re, im, base, &offs, &mr, &mi, dim);
        }
        return;
    }
    let mut vr = [0f64; 8];
    let mut vi = [0f64; 8];
    for base in subspace_bases(len, bits) {
        for s in 0..dim {
            let ix = base | offs[s];
            vr[s] = re[ix];
            vi[s] = im[ix];
        }
        for r in 0..dim {
            let (mrow, irow) = (&mr[r], &mi[r]);
            let mut ar = 0.0;
            let mut ai = 0.0;
            for s in 0..dim {
                ar += mrow[s] * vr[s] - irow[s] * vi[s];
                ai += mrow[s] * vi[s] + irow[s] * vr[s];
            }
            let ix = base | offs[r];
            re[ix] = ar;
            im[ix] = ai;
        }
    }
}

/// Iterate base indices with every bit of `bits` (sorted ascending) clear
/// — the k-bit generalization of `pair_indices`/`quad_indices`.
#[inline(always)]
pub fn subspace_bases(len: usize, bits: &[usize]) -> impl Iterator<Item = usize> + '_ {
    let k = bits.len();
    (0..len >> k).map(move |t| {
        let mut idx = t;
        // Insert a zero at each support position, ascending: lower
        // insertions do not disturb the positions of later ones.
        for &b in bits {
            let low = idx & ((1usize << b) - 1);
            idx = ((idx & !((1usize << b) - 1)) << 1) | low;
        }
        idx
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuit::fusion::fuse_gates;
    use crate::circuit::{Circuit, Gate, GateKind};
    use crate::gates::apply_gate;
    use crate::types::SplitMix64;

    fn random_planes(n: usize, seed: u64) -> (Vec<f64>, Vec<f64>) {
        let mut rng = SplitMix64::new(seed);
        let len = 1usize << n;
        (
            (0..len).map(|_| rng.next_gaussian()).collect(),
            (0..len).map(|_| rng.next_gaussian()).collect(),
        )
    }

    fn assert_planes_close(
        a_re: &[f64],
        a_im: &[f64],
        b_re: &[f64],
        b_im: &[f64],
        tol: f64,
        tag: &str,
    ) {
        // `<=` so tol = 0.0 demands exact (bit-identical) equality.
        for i in 0..a_re.len() {
            assert!(
                (a_re[i] - b_re[i]).abs() <= tol && (a_im[i] - b_im[i]).abs() <= tol,
                "{tag}: amp {i}: ({}, {}) vs ({}, {})",
                a_re[i],
                a_im[i],
                b_re[i],
                b_im[i]
            );
        }
    }

    #[test]
    fn subspace_bases_cover_all_sites() {
        let len = 64;
        for bits in [vec![0usize], vec![2], vec![0, 3], vec![1, 2, 5], vec![3, 4, 5]] {
            let mask: usize = bits.iter().map(|&b| 1usize << b).sum();
            let mut seen = vec![false; len];
            for base in subspace_bases(len, &bits) {
                assert_eq!(base & mask, 0);
                for s in 0..(1usize << bits.len()) {
                    let mut ix = base;
                    for (j, &b) in bits.iter().enumerate() {
                        if s & (1 << j) != 0 {
                            ix |= 1 << b;
                        }
                    }
                    assert!(!seen[ix], "bits {bits:?} idx {ix} twice");
                    seen[ix] = true;
                }
            }
            assert!(seen.iter().all(|&s| s), "bits {bits:?} missed sites");
        }
    }

    #[test]
    fn fused_kernels_match_per_gate_kernels_per_kind() {
        use GateKind::*;
        let n = 6;
        // Runs chosen to produce k = 1, 2 and 3 ops across gate kinds.
        let runs: Vec<Vec<Gate>> = vec![
            vec![Gate::q1(H, 4).unwrap(), Gate::q1(T, 4).unwrap()],
            vec![Gate::q2(Cx, 5, 1).unwrap(), Gate::q1(Rz(0.7), 5).unwrap()],
            vec![
                Gate::q2(Rxx(0.4), 0, 3).unwrap(),
                Gate::q2(Cp(0.9), 3, 5).unwrap(),
                Gate::q1(Sx, 0).unwrap(),
            ],
            vec![
                Gate::q2(Swap, 2, 4).unwrap(),
                Gate::q2(Cry(-1.1), 4, 2).unwrap(),
                Gate::q2(Cz, 2, 0).unwrap(),
            ],
        ];
        for (ri, gates) in runs.iter().enumerate() {
            let ops = fuse_gates(gates, 3);
            assert_eq!(ops.len(), 1, "run {ri} did not fuse");
            let (re_ref, im_ref) = random_planes(n, ri as u64 + 5);
            let mut want = (re_ref.clone(), im_ref.clone());
            for g in gates {
                apply_gate(&mut want.0, &mut want.1, g);
            }
            let mut got = (re_ref.clone(), im_ref.clone());
            apply_fused(&mut got.0, &mut got.1, &ops[0]);
            assert_planes_close(&got.0, &got.1, &want.0, &want.1, 1e-12, &format!("run {ri}"));
        }
    }

    #[test]
    fn apply_stage_matches_sequential_for_all_tiles_and_workers() {
        use GateKind::*;
        let n = 9;
        let mut rng = SplitMix64::new(31);
        let mut c = Circuit::new(n, "mix");
        for step in 0..80 {
            let q = (rng.next_u64() as usize) % n;
            let mut p = (rng.next_u64() as usize) % n;
            while p == q {
                p = (rng.next_u64() as usize) % n;
            }
            let th = rng.next_f64();
            match step % 5 {
                0 => c.h(q),
                1 => c.rz(th, q),
                2 => c.cx(q, p),
                3 => c.rxx(th, q, p),
                _ => c.cp(th, q, p),
            };
        }
        let (re0, im0) = random_planes(n, 404);
        let mut want = (re0.clone(), im0.clone());
        for g in &c.gates {
            apply_gate(&mut want.0, &mut want.1, g);
        }
        let ops = fuse_gates(&c.gates, 3);
        assert!(ops.len() < c.gates.len(), "no fusion happened");
        for tile_bits in [2usize, 4, 6, 9, 30] {
            for workers in [1usize, 2, 4] {
                let mut got = (re0.clone(), im0.clone());
                let stats = apply_stage(&mut got.0, &mut got.1, &ops, tile_bits, workers);
                assert_eq!(stats.fused_ops_applied, ops.len() as u64);
                assert_eq!(
                    stats.sweeps,
                    stage_sweeps(&ops, n, tile_bits),
                    "tile={tile_bits}"
                );
                assert!(stats.sweeps <= ops.len() as u64);
                assert_planes_close(
                    &got.0,
                    &got.1,
                    &want.0,
                    &want.1,
                    1e-12,
                    &format!("tile={tile_bits} workers={workers}"),
                );
            }
        }
    }

    #[test]
    fn tiled_runs_collapse_sweeps() {
        // Local ops on DISJOINT low supports cannot fuse (union > 3) but
        // still share one tiled sweep: 4 ops, 1 sweep.
        let mut c = Circuit::new(8, "low");
        c.cx(0, 1).cx(2, 3).cx(0, 2).cx(1, 3);
        let ops = fuse_gates(&c.gates, 2);
        assert_eq!(ops.len(), 4);
        assert_eq!(stage_sweeps(&ops, 8, 4), 1);
    }

    #[test]
    fn local_run_is_one_sweep_high_ops_sweep_alone() {
        let mut c = Circuit::new(10, "hi-lo");
        // A local op, a high op, another local op — each pairwise union
        // exceeds k=3, so the three runs stay separate.
        c.h(0).cx(0, 1); // fuses to one op, max_bit 1
        c.cx(9, 8); // high op, max_bit 9
        c.cx(2, 3).rz(0.1, 2); // fuses, max_bit 3
        let ops = fuse_gates(&c.gates, 3);
        assert_eq!(ops.len(), 3);
        // tile_bits=5: [local][high][local] -> 3 sweeps.
        assert_eq!(stage_sweeps(&ops, 10, 5), 3);
        // tile_bits=10: everything local -> ONE sweep for all three.
        assert_eq!(stage_sweeps(&ops, 10, 10), 1);
        let (mut re, mut im) = random_planes(10, 8);
        let mut want = (re.clone(), im.clone());
        for g in &c.gates {
            apply_gate(&mut want.0, &mut want.1, g);
        }
        let stats = apply_stage(&mut re, &mut im, &ops, 5, 2);
        assert_eq!(stats.sweeps, 3);
        assert_planes_close(&re, &im, &want.0, &want.1, 1e-12, "hi-lo");
    }

    #[test]
    fn deep_same_qubit_run_needs_fewer_sweeps_than_gates() {
        // The satellite assertion: a deep run on one qubit is ONE fused op
        // and ONE sweep, against `gates` sweeps for the per-gate path.
        let mut c = Circuit::new(12, "deep");
        for i in 0..200 {
            if i % 2 == 0 {
                c.t(3);
            } else {
                c.h(3);
            }
        }
        let ops = fuse_gates(&c.gates, 3);
        assert_eq!(ops.len(), 1);
        let sweeps = stage_sweeps(&ops, 12, DEFAULT_TILE_BITS);
        assert_eq!(sweeps, 1);
        assert!((sweeps as usize) < c.gates.len());
    }

    #[test]
    fn apply_gate_parallel_matches_serial() {
        let n = 8;
        for (kind, qs) in [
            (GateKind::H, vec![6usize]),
            (GateKind::X, vec![0]),
            (GateKind::Rz(0.9), vec![7]),
        ] {
            let gate = Gate::q1(kind, qs[0]).unwrap();
            let (re0, im0) = random_planes(n, 99);
            let mut want = (re0.clone(), im0.clone());
            apply_gate(&mut want.0, &mut want.1, &gate);
            for workers in [1usize, 2, 4] {
                let mut got = (re0.clone(), im0.clone());
                apply_gate_parallel(&mut got.0, &mut got.1, &gate, &qs, workers);
                assert_planes_close(
                    &got.0,
                    &got.1,
                    &want.0,
                    &want.1,
                    0.0,
                    &format!("{kind:?} workers={workers}"),
                );
            }
        }
        let gate = Gate::q2(GateKind::Cx, 7, 2).unwrap();
        let (re0, im0) = random_planes(n, 100);
        let mut want = (re0.clone(), im0.clone());
        apply_gate(&mut want.0, &mut want.1, &gate);
        for workers in [1usize, 3] {
            let mut got = (re0.clone(), im0.clone());
            apply_gate_parallel(&mut got.0, &mut got.1, &gate, &[7, 2], workers);
            assert_planes_close(&got.0, &got.1, &want.0, &want.1, 0.0, "cx par");
        }
    }

    #[test]
    fn apply_gate_parallel_spans_multiple_chunks() {
        // Planes ABOVE the 2^14-amplitude chunk floor: the sweep really
        // splits (4 chunks for H on bit 13, 2 for CX on bit 14), so this
        // exercises the threaded path that smaller test planes collapse
        // into a single inline chunk. Rz on the top bit is the boundary
        // case that stays one chunk by construction.
        let n = 16;
        let (re0, im0) = random_planes(n, 1234);
        for (gate, bits) in [
            (Gate::q1(GateKind::H, 13).unwrap(), vec![13usize]),
            (Gate::q2(GateKind::Cx, 14, 1).unwrap(), vec![14, 1]),
            (Gate::q1(GateKind::Rz(0.31), 15).unwrap(), vec![15]),
        ] {
            let mut want = (re0.clone(), im0.clone());
            apply_gate(&mut want.0, &mut want.1, &gate);
            for workers in [2usize, 3, 4] {
                let mut got = (re0.clone(), im0.clone());
                apply_gate_parallel(&mut got.0, &mut got.1, &gate, &bits, workers);
                assert_planes_close(
                    &got.0,
                    &got.1,
                    &want.0,
                    &want.1,
                    0.0,
                    &format!("{:?} workers={workers}", gate.kind),
                );
            }
        }
    }
}
