//! Gate application onto amplitude planes: general 1q/2q paths, diagonal
//! fast paths, and permutation specializations for X/CX/SWAP.

use super::{pair_indices, quad_indices};
use crate::circuit::{Gate, GateKind};
use crate::types::Complex;

/// Apply `gate` to a buffer whose bit positions equal circuit qubits
/// (dense engine path).
pub fn apply_gate(re: &mut [f64], im: &mut [f64], gate: &Gate) {
    let targets: Vec<usize> = gate.targets().to_vec();
    apply_gate_remapped(re, im, gate, &targets);
}

/// Apply `gate` with explicit buffer bit positions for its targets
/// (SV-group path: positions come from `GroupSchedule::buffer_bit`).
pub fn apply_gate_remapped(re: &mut [f64], im: &mut [f64], gate: &Gate, bits: &[usize]) {
    debug_assert_eq!(re.len(), im.len());
    debug_assert!(re.len().is_power_of_two());
    match gate.arity() {
        1 => apply_1q(re, im, gate, bits[0]),
        _ => apply_2q(re, im, gate, bits[0], bits[1]),
    }
}

fn apply_1q(re: &mut [f64], im: &mut [f64], gate: &Gate, t: usize) {
    let len = re.len();
    let bit = 1usize << t;
    match gate.kind {
        // --- permutation / sign specializations (hot in the benchmarks) ---
        GateKind::X => {
            for i0 in pair_indices(len, t) {
                re.swap(i0, i0 | bit);
                im.swap(i0, i0 | bit);
            }
        }
        GateKind::Z => {
            for i0 in pair_indices(len, t) {
                let i1 = i0 | bit;
                re[i1] = -re[i1];
                im[i1] = -im[i1];
            }
        }
        _ if gate.kind.is_diagonal() => {
            let d = gate.diagonal();
            apply_1q_diag(re, im, t, d[0], d[1]);
        }
        _ => {
            let m = gate.matrix1q();
            super::dense_1q(&m, re, im, bit);
        }
    }
}

/// Element-wise diagonal 1q path: `a_i *= d[bit_t(i)]`.
fn apply_1q_diag(re: &mut [f64], im: &mut [f64], t: usize, d0: Complex, d1: Complex) {
    let len = re.len();
    let bit = 1usize << t;
    // Skip multiplies entirely when d0 == 1 (Z-family gates): touch only
    // the bit-set half.
    let d0_is_one = d0.approx_eq(Complex::ONE, 0.0);
    if d0_is_one {
        for i0 in pair_indices(len, t) {
            let i1 = i0 | bit;
            let (r, i) = (re[i1], im[i1]);
            re[i1] = d1.re * r - d1.im * i;
            im[i1] = d1.re * i + d1.im * r;
        }
    } else {
        for i0 in pair_indices(len, t) {
            let i1 = i0 | bit;
            let (r0, v0) = (re[i0], im[i0]);
            re[i0] = d0.re * r0 - d0.im * v0;
            im[i0] = d0.re * v0 + d0.im * r0;
            let (r1, v1) = (re[i1], im[i1]);
            re[i1] = d1.re * r1 - d1.im * v1;
            im[i1] = d1.re * v1 + d1.im * r1;
        }
    }
}

fn apply_2q(re: &mut [f64], im: &mut [f64], gate: &Gate, qa: usize, qb: usize) {
    let len = re.len();
    // Matrix basis: |q_a q_b> with q_a (qubits[0]) the HIGH bit. The quad
    // iterator wants hi > lo as buffer positions; track where each matrix
    // index lands. The hi/lo pair and the four basis-pattern offsets are
    // loop invariants — hoisted so the inner loops are pure index | offset.
    let (ba, bb) = (1usize << qa, 1usize << qb);
    let (hi, lo) = (qa.max(qb), qa.min(qb));
    let off10 = ba;
    let off01 = bb;
    let off11 = ba | bb;
    match gate.kind {
        GateKind::Cx => {
            // control = qa, target = qb: swap amplitudes where control set.
            for i in quad_indices(len, hi, lo) {
                let i10 = i | off10;
                let i11 = i | off11;
                re.swap(i10, i11);
                im.swap(i10, i11);
            }
        }
        GateKind::Swap => {
            for i in quad_indices(len, hi, lo) {
                let i01 = i | off01;
                let i10 = i | off10;
                re.swap(i01, i10);
                im.swap(i01, i10);
            }
        }
        GateKind::Cz => {
            for i in quad_indices(len, hi, lo) {
                let i11 = i | off11;
                re[i11] = -re[i11];
                im[i11] = -im[i11];
            }
        }
        _ if gate.kind.is_diagonal() => {
            // Pre-filter the identity entries once (Z-family gates have
            // d[0..3] == 1) instead of testing every entry per quad.
            let d = gate.diagonal();
            let offs = [0usize, off01, off10, off11]; // |00>,|01>,|10>,|11>
            let mut active = [(0usize, Complex::ZERO); 4];
            let mut na = 0usize;
            for (pat, dv) in d.iter().enumerate() {
                if !dv.approx_eq(Complex::ONE, 0.0) {
                    active[na] = (offs[pat], *dv);
                    na += 1;
                }
            }
            let active = &active[..na];
            for i in quad_indices(len, hi, lo) {
                for &(off, dv) in active {
                    let idx = i | off;
                    let (r, v) = (re[idx], im[idx]);
                    re[idx] = dv.re * r - dv.im * v;
                    im[idx] = dv.re * v + dv.im * r;
                }
            }
        }
        _ => {
            let m = gate.matrix2q();
            for i in quad_indices(len, hi, lo) {
                let idx = [i, i | off01, i | off10, i | off11]; // |00>,|01>,|10>,|11>
                let mut vr = [0.0f64; 4];
                let mut vi = [0.0f64; 4];
                for (s, &ix) in idx.iter().enumerate() {
                    vr[s] = re[ix];
                    vi[s] = im[ix];
                }
                for (r, &ix) in idx.iter().enumerate() {
                    let mut ar = 0.0;
                    let mut ai = 0.0;
                    for s in 0..4 {
                        let mc = m[r * 4 + s];
                        ar += mc.re * vr[s] - mc.im * vi[s];
                        ai += mc.re * vi[s] + mc.im * vr[s];
                    }
                    re[ix] = ar;
                    im[ix] = ai;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuit::{Gate, GateKind};
    use crate::state::StateVector;
    use crate::types::SplitMix64;

    /// Brute-force reference: build the full 2^n x 2^n action by expanding
    /// the gate unitary over the target bits.
    fn apply_ref(s: &StateVector, gate: &Gate) -> StateVector {
        let n = s.n_qubits;
        let len = 1usize << n;
        let mut re = vec![0.0; len];
        let mut im = vec![0.0; len];
        match gate.arity() {
            1 => {
                let m = gate.matrix1q();
                let t = gate.qubits[0];
                for out in 0..len {
                    let ob = (out >> t) & 1;
                    for ib in 0..2usize {
                        let input = (out & !(1 << t)) | (ib << t);
                        let c = m[ob * 2 + ib];
                        re[out] += c.re * s.re[input] - c.im * s.im[input];
                        im[out] += c.re * s.im[input] + c.im * s.re[input];
                    }
                }
            }
            _ => {
                let m = gate.matrix2q();
                let (qa, qb) = (gate.qubits[0], gate.qubits[1]);
                for out in 0..len {
                    let oa = (out >> qa) & 1;
                    let ob = (out >> qb) & 1;
                    let orow = oa * 2 + ob;
                    for irow in 0..4usize {
                        let (ia, ib) = (irow >> 1, irow & 1);
                        let input = (out & !(1 << qa) & !(1 << qb)) | (ia << qa) | (ib << qb);
                        let c = m[orow * 4 + irow];
                        re[out] += c.re * s.re[input] - c.im * s.im[input];
                        im[out] += c.re * s.im[input] + c.im * s.re[input];
                    }
                }
            }
        }
        StateVector::from_planes(n, re, im).unwrap()
    }

    fn random_state(n: usize, seed: u64) -> StateVector {
        let mut rng = SplitMix64::new(seed);
        let len = 1usize << n;
        let re: Vec<f64> = (0..len).map(|_| rng.next_gaussian()).collect();
        let im: Vec<f64> = (0..len).map(|_| rng.next_gaussian()).collect();
        StateVector::from_planes(n, re, im).unwrap()
    }

    fn assert_close(a: &StateVector, b: &StateVector, tol: f64) {
        for i in 0..a.len() {
            assert!(
                (a.re[i] - b.re[i]).abs() < tol && (a.im[i] - b.im[i]).abs() < tol,
                "amplitude {i}: ({}, {}) vs ({}, {})",
                a.re[i],
                a.im[i],
                b.re[i],
                b.im[i]
            );
        }
    }

    #[test]
    fn every_1q_kind_matches_bruteforce_on_every_target() {
        use GateKind::*;
        let kinds = [
            X, Y, Z, H, S, Sdg, T, Tdg, Sx, Rx(0.7), Ry(-0.4), Rz(1.9), P(0.33),
            U3(0.3, 1.2, -0.8),
        ];
        for n in [1usize, 3, 5] {
            for t in 0..n {
                for (ki, kind) in kinds.iter().enumerate() {
                    let s = random_state(n, (n * 100 + t * 10 + ki) as u64);
                    let gate = Gate::q1(*kind, t).unwrap();
                    let want = apply_ref(&s, &gate);
                    let mut got = s.clone();
                    apply_gate(&mut got.re, &mut got.im, &gate);
                    assert_close(&got, &want, 1e-12);
                }
            }
        }
    }

    #[test]
    fn every_2q_kind_matches_bruteforce_on_every_pair() {
        use GateKind::*;
        let kinds = [
            Cx, Cy, Cz, Swap, Cp(0.9), Crx(0.5), Cry(-1.1), Crz(2.0), Rxx(0.6), Rzz(-0.3),
        ];
        for n in [2usize, 4] {
            for qa in 0..n {
                for qb in 0..n {
                    if qa == qb {
                        continue;
                    }
                    for (ki, kind) in kinds.iter().enumerate() {
                        let s = random_state(n, (n * 1000 + qa * 100 + qb * 10 + ki) as u64);
                        let gate = Gate::q2(*kind, qa, qb).unwrap();
                        let want = apply_ref(&s, &gate);
                        let mut got = s.clone();
                        apply_gate(&mut got.re, &mut got.im, &gate);
                        assert_close(&got, &want, 1e-12);
                    }
                }
            }
        }
    }

    #[test]
    fn bell_state_construction() {
        let mut s = StateVector::zero_state(2).unwrap();
        apply_gate(&mut s.re, &mut s.im, &Gate::q1(GateKind::H, 0).unwrap());
        apply_gate(&mut s.re, &mut s.im, &Gate::q2(GateKind::Cx, 0, 1).unwrap());
        let h = std::f64::consts::FRAC_1_SQRT_2;
        assert!((s.re[0] - h).abs() < 1e-15);
        assert!((s.re[3] - h).abs() < 1e-15); // |11>
        assert!(s.re[1].abs() < 1e-15 && s.re[2].abs() < 1e-15);
    }

    #[test]
    fn remapped_application() {
        // Apply H "on qubit 5" of a 3-bit buffer via remap to bit 1: must
        // equal applying H on bit 1 directly.
        let s = random_state(3, 77);
        let gate = Gate::q1(GateKind::H, 5).unwrap(); // absolute qubit
        let mut got = s.clone();
        apply_gate_remapped(&mut got.re, &mut got.im, &gate, &[1]);
        let mut want = s.clone();
        apply_gate(&mut want.re, &mut want.im, &Gate::q1(GateKind::H, 1).unwrap());
        assert_close(&got, &want, 1e-15);
    }

    #[test]
    fn unitarity_preserves_norm_through_random_circuit() {
        let mut s = StateVector::zero_state(6).unwrap();
        let mut rng = SplitMix64::new(5);
        for step in 0..50 {
            let q = (step * 7) % 6;
            let gate = match step % 4 {
                0 => Gate::q1(GateKind::H, q).unwrap(),
                1 => Gate::q1(GateKind::Rx(rng.next_f64()), q).unwrap(),
                2 => Gate::q2(GateKind::Cx, q, (q + 1) % 6).unwrap(),
                _ => Gate::q2(GateKind::Rzz(rng.next_f64()), q, (q + 3) % 6).unwrap(),
            };
            apply_gate(&mut s.re, &mut s.im, &gate);
            assert!((s.norm_sq() - 1.0).abs() < 1e-10, "step {step}");
        }
    }

    #[test]
    fn cx_control_target_order_matters() {
        // |10> (qubit1=1): CX(1,0) flips target 0 -> |11>; CX(0,1) is identity.
        let mut re = vec![0.0; 4];
        re[2] = 1.0;
        let s = StateVector::from_planes(2, re, vec![0.0; 4]).unwrap();
        let mut a = s.clone();
        apply_gate(&mut a.re, &mut a.im, &Gate::q2(GateKind::Cx, 1, 0).unwrap());
        assert!((a.re[3] - 1.0).abs() < 1e-15);
        let mut b = s.clone();
        apply_gate(&mut b.re, &mut b.im, &Gate::q2(GateKind::Cx, 0, 1).unwrap());
        assert!((b.re[2] - 1.0).abs() < 1e-15);
    }
}
