//! Terminal measurement: basis-state sampling and marginal statistics.
//!
//! Like the paper's simulators (SV-Sim et al.), measurement is performed
//! once at the end of the circuit from the final state vector (or its
//! decompressed blocks), not mid-circuit.

use crate::state::StateVector;
use crate::types::SplitMix64;
use std::collections::BTreeMap;

/// Draw `shots` basis-state samples from the state's probability
/// distribution; returns a `basis index -> count` histogram.
pub fn sample_counts(state: &StateVector, shots: usize, rng: &mut SplitMix64) -> BTreeMap<usize, usize> {
    // Inverse-CDF sampling over sorted uniform draws: one O(N + shots) pass
    // instead of shots binary searches.
    let mut draws: Vec<f64> = (0..shots).map(|_| rng.next_f64()).collect();
    draws.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mut counts = BTreeMap::new();
    let mut acc = 0.0f64;
    let mut d = 0usize;
    for i in 0..state.len() {
        acc += state.probability(i);
        while d < draws.len() && draws[d] < acc {
            *counts.entry(i).or_insert(0) += 1;
            d += 1;
        }
        if d == draws.len() {
            break;
        }
    }
    // Numerical tail: any residual draws (norm slightly < 1) hit the last state.
    if d < draws.len() {
        *counts.entry(state.len() - 1).or_insert(0) += draws.len() - d;
    }
    counts
}

/// Per-qubit marginal P(q = 1) vector.
pub fn marginals(state: &StateVector) -> Vec<f64> {
    let n = state.n_qubits;
    let mut p = vec![0.0f64; n];
    for i in 0..state.len() {
        let prob = state.probability(i);
        if prob == 0.0 {
            continue;
        }
        let mut bits = i;
        while bits != 0 {
            let q = bits.trailing_zeros() as usize;
            p[q] += prob;
            bits &= bits - 1;
        }
    }
    p
}

/// Expectation of Z on qubit `q`: `P(0) - P(1)`.
pub fn expect_z(state: &StateVector, q: usize) -> f64 {
    1.0 - 2.0 * state.prob_qubit_one(q)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuit::{Gate, GateKind};
    use crate::gates::apply_gate;

    #[test]
    fn sampling_zero_state_always_zero() {
        let s = StateVector::zero_state(4).unwrap();
        let mut rng = SplitMix64::new(1);
        let counts = sample_counts(&s, 1000, &mut rng);
        assert_eq!(counts.len(), 1);
        assert_eq!(counts[&0], 1000);
    }

    #[test]
    fn sampling_uniform_superposition_is_roughly_flat() {
        let mut s = StateVector::zero_state(3).unwrap();
        for q in 0..3 {
            apply_gate(&mut s.re, &mut s.im, &Gate::q1(GateKind::H, q).unwrap());
        }
        let mut rng = SplitMix64::new(2);
        let shots = 80_000;
        let counts = sample_counts(&s, shots, &mut rng);
        assert_eq!(counts.len(), 8);
        for (_, &c) in &counts {
            let f = c as f64 / shots as f64;
            assert!((f - 0.125).abs() < 0.01, "freq {f}");
        }
    }

    #[test]
    fn sample_total_equals_shots() {
        let mut s = StateVector::zero_state(5).unwrap();
        apply_gate(&mut s.re, &mut s.im, &Gate::q1(GateKind::H, 2).unwrap());
        let mut rng = SplitMix64::new(3);
        let counts = sample_counts(&s, 12345, &mut rng);
        let total: usize = counts.values().sum();
        assert_eq!(total, 12345);
    }

    #[test]
    fn marginals_of_bell_state() {
        let mut s = StateVector::zero_state(2).unwrap();
        apply_gate(&mut s.re, &mut s.im, &Gate::q1(GateKind::H, 0).unwrap());
        apply_gate(&mut s.re, &mut s.im, &Gate::q2(GateKind::Cx, 0, 1).unwrap());
        let m = marginals(&s);
        assert!((m[0] - 0.5).abs() < 1e-12);
        assert!((m[1] - 0.5).abs() < 1e-12);
    }

    #[test]
    fn expect_z_signs() {
        let s = StateVector::zero_state(2).unwrap();
        assert!((expect_z(&s, 0) - 1.0).abs() < 1e-15);
        let mut s1 = s.clone();
        apply_gate(&mut s1.re, &mut s1.im, &Gate::q1(GateKind::X, 1).unwrap());
        assert!((expect_z(&s1, 1) + 1.0).abs() < 1e-15);
    }
}
