//! Native gate-application kernels over split re/im amplitude planes.
//!
//! These operate on any power-of-two buffer: the full dense state (dense
//! engine) or a gathered SV-group buffer (compressed engines), with target
//! qubits already remapped to buffer bit positions.
//!
//! Layout conventions match §2.1 of the paper: applying a 1q gate on qubit
//! `t` multiplies the 2x2 unitary into every amplitude pair whose indices
//! differ only in bit `t`; a 2q gate on `(q, t)` multiplies the 4x4 unitary
//! into quads in basis order `|q t> = 00,01,10,11` (q the high bit).
//!
//! Diagonal gates use an element-wise fast path (no pair addressing), the
//! same specialization the L1 Pallas kernel set exposes (`diag1q/diag2q`).
//!
//! [`fused`] holds the batched stage kernels: whole fused-op lists
//! ([`crate::circuit::fusion`]) applied in cache-blocked, worker-parallel
//! plane sweeps — see DESIGN.md §"Gate fusion & sweep model".

pub mod apply;
pub mod fused;
pub mod measure;

pub use apply::{apply_gate, apply_gate_remapped};
pub use fused::{apply_fused, apply_gate_parallel, apply_stage, StageStats};

use crate::types::Complex;

/// Dense 1q mat-vec over a whole plane, shared by the per-gate
/// (`apply.rs`) and fused (`fused.rs`) paths so the hot loop exists once.
///
/// Perf (§Perf): block-contiguous traversal — the inner loop runs over
/// `bit` consecutive indices in both halves of each `2*bit`-aligned
/// block, which vectorizes and streams, unlike the generic bit-interleave
/// of [`pair_indices`].
#[inline]
pub(crate) fn dense_1q(m: &[Complex], re: &mut [f64], im: &mut [f64], bit: usize) {
    debug_assert!(m.len() >= 4);
    // Flatten to the interleaved (re, im) form the SIMD tables take; the
    // selected kernel is bit-identical to the historical scalar loop (the
    // oracle lives in `simd::scalar::dense_1q`).
    let mf = [m[0].re, m[0].im, m[1].re, m[1].im, m[2].re, m[2].im, m[3].re, m[3].im];
    crate::simd::dispatch().dense_1q(&mf, re, im, bit);
}

/// Iterate amplitude-pair base indices for target bit `t` in a buffer of
/// `len` amplitudes: yields `i0` with bit `t` clear; the partner is
/// `i0 | (1 << t)`.
///
/// `inline(always)` (here and on [`quad_indices`]): the map closure must
/// inline into the caller's loop so the compiler sees the index algebra,
/// proves `i0 | bit < len`, and drops the bounds checks in the kernels'
/// inner loops.
#[inline(always)]
pub fn pair_indices(len: usize, t: usize) -> impl Iterator<Item = usize> {
    let bit = 1usize << t;
    let low_mask = bit - 1;
    (0..len / 2).map(move |k| {
        let lo = k & low_mask;
        let hi = (k & !low_mask) << 1;
        hi | lo
    })
}

/// Iterate quad base indices for target bits `q > t` (as buffer positions):
/// yields `i00` with both bits clear.
#[inline(always)]
pub fn quad_indices(len: usize, hi_bit: usize, lo_bit: usize) -> impl Iterator<Item = usize> {
    debug_assert!(hi_bit > lo_bit);
    let b_lo = 1usize << lo_bit;
    let b_hi = 1usize << hi_bit;
    let m_lo = b_lo - 1;
    // mask of bits strictly between lo_bit and hi_bit (after low removal)
    let m_mid = (b_hi >> 1) - b_lo;
    (0..len / 4).map(move |k| {
        let lo = k & m_lo;
        let mid = (k & m_mid) << 1;
        let hi = (k & !(m_lo | m_mid)) << 2;
        hi | mid | lo
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pair_indices_cover_all_pairs() {
        for t in 0..4 {
            let len = 16;
            let bit = 1usize << t;
            let mut seen = vec![false; len];
            for i0 in pair_indices(len, t) {
                assert_eq!(i0 & bit, 0);
                assert!(!seen[i0] && !seen[i0 | bit]);
                seen[i0] = true;
                seen[i0 | bit] = true;
            }
            assert!(seen.iter().all(|&s| s), "t={t}");
        }
    }

    #[test]
    fn quad_indices_cover_all_quads() {
        let len = 32;
        for hi in 1..5 {
            for lo in 0..hi {
                let (bh, bl) = (1usize << hi, 1usize << lo);
                let mut seen = vec![false; len];
                for i in quad_indices(len, hi, lo) {
                    assert_eq!(i & (bh | bl), 0);
                    for idx in [i, i | bl, i | bh, i | bh | bl] {
                        assert!(!seen[idx], "hi={hi} lo={lo} idx={idx}");
                        seen[idx] = true;
                    }
                }
                assert!(seen.iter().all(|&s| s), "hi={hi} lo={lo}");
            }
        }
    }
}
