//! BMQSIM command-line interface (L3 leader entrypoint).
//!
//! Subcommands:
//!   run        simulate a benchmark circuit or a .qasm file
//!   partition  show the Algorithm-1 stage decomposition of a circuit
//!   compare    run an engine against the dense ideal and report fidelity
//!   sample     draw measurement shots from the final state
//!   report     regenerate the paper's tables/figures (same harness as
//!              `cargo bench`, at CLI-chosen scale)
//!
//! Args are parsed by hand (the build environment vendors no clap; see
//! DESIGN.md substitutions). `bmqsim help` prints the full usage.

use bmqsim::bench_harness as bench;
use bmqsim::circuit::{generators, partition_circuit, qasm, Circuit};
use bmqsim::compress::Codec;
use bmqsim::gates::measure;
use bmqsim::memory::xxh64;
use bmqsim::pipeline::PipelineConfig;
use bmqsim::runtime::XlaApplier;
use bmqsim::sim::{Backend, BmqSim, DenseSim, OverlapMode, Sc19Sim, SimConfig, SimResult};
use bmqsim::types::{fmt_bytes, standard_memory_bytes, Error, Precision, SplitMix64};
use std::collections::HashMap;

const USAGE: &str = r#"bmqsim — memory-constrained state-vector quantum simulation

USAGE:
  bmqsim run       --algo <name>|--qasm <file> --qubits <n> [options]
  bmqsim partition --algo <name>|--qasm <file> --qubits <n> [--block-qubits B] [--inner-size I]
  bmqsim compare   --algo <name> --qubits <n> [options]
  bmqsim sample    --algo <name> --qubits <n> --shots <k> [options]
  bmqsim report    [--scale small|full]
  bmqsim help

OPTIONS (run/compare/sample):
  --engine <bmqsim|dense|sc19-cpu|sc19-gpu>   engine to run        [bmqsim]
  --backend <native|xla>                      gate kernels         [native]
  --block-qubits <B>    log2 SV block length                       [14]
  --inner-size <I>      Algorithm-1 inner threshold                [2]
  --error-bound <e>     point-wise relative bound                  [1e-3]
  --fidelity-target <f> whole-run fidelity floor in (0,1): derive every
                        block's bound from a shared error budget instead
                        of the fixed --error-bound (requires the
                        point-wise codec, i.e. not --no-compress)   [off]
  --error-policy <p>    how the budget is split per encode round:
                        "global" (uniform bound) or "amplitude"
                        (per-block, shaped by amplitude mass; heavy
                        blocks tighten, near-zero blocks relax)   [global]
  --no-compress         disable compression (raw blocks)
  --no-prescan          disable the sign-bitmap pre-scan
  --no-fusion           disable gate fusion (per-gate application)
  --no-simd             pin the scalar codec/gate kernels (vector and
                        scalar paths are byte-identical; diagnostic knob.
                        env: BMQSIM_NO_SIMD pins it process-wide)
  --max-fuse <K>        fused-unitary width cap (1..=3)            [3]
  --tile-bits <T>       log2 amplitudes per cache tile             [15]
  --apply-workers <W>   parallel plane-sweep workers per chain     [1]
  --streams <S>         pipeline streams per device                [2]
  --devices <D>         logical devices                            [1]
  --overlap             always overlap decode/apply/encode per worker on the
                        persistent 3-phase pipeline; --no-overlap pins it
                        off. Omitting both auto-decides per stage from
                        group size x measured codec cost             [auto]
  --no-overlap          never overlap (strictly sequential worker chains)
  --cross-stage         always let the next stage's decode start while the
                        previous stage's encoders drain (stitched schedules
                        + shared-block boundary gates); --no-cross-stage
                        pins the per-stage barrier. Omitting both follows
                        the overlap mode (on unless --no-overlap)    [auto]
  --no-cross-stage      always drain each stage fully before the next
  --pipeline-depth <K>  scratch slots per worker ring (overlap); when
                        omitted the depth auto-adapts per stage (AIMD on
                        handshake stall imbalance, band [2, 8])     [auto]
  --no-spill-order      disable spill-aware group ordering (resident
                        groups first) within each stage
  --memory-budget <MB>  primary-tier budget in MiB (enables probing)
  --spill-dir <path>    secondary-tier directory (enables spilling)
  --store-shards <N>    lock shards in the two-level store             [8]
  --prefetch-depth <G>  groups the spill prefetcher stages ahead; when
                        omitted the depth auto-adapts per stage (AIMD
                        on hit/miss ratio + stall time)             [auto]
  --sync-spill          spill inline on workers (no background writer)
  --spill-fallback-dir <path>  overflow stripe for ENOSPC graceful
                        degradation (ideally a different filesystem)
  --fault-plan <spec>   inject spill-layer I/O faults for resilience
                        testing, e.g. "seed=7,eio=0.05,bitflip=0.02" or
                        scripted "eio@write:1" / "kill@manifest"
                        (env: BMQSIM_FAULT_PLAN)
  --checkpoint-dir <d>  write crash-consistent snapshots under <d> at stage
                        boundaries (bmqsim) / gate boundaries (sc19):
                        compressed blocks + an atomically-renamed manifest
  --checkpoint-every <N>  snapshot cadence in completed stages      [1]
  --checkpoint-keep <N>   most-recent checkpoints retained          [2]
  --resume <dir>        rehydrate the newest intact checkpoint under <dir>
                        and continue from its stage cursor; the run config
                        must fingerprint-match the checkpoint (exit 4)
  --stall-timeout-ms <ms>  watchdog on pipeline boundary/drain waits: a
                        hang with no progress for <ms> becomes a typed
                        error instead of a wedge                [off]
  --artifacts <dir>     AOT artifact directory                     [artifacts]
  --seed <s>            circuit/sampling seed                      [42]

BENCHMARK ALGORITHMS: cat_state cc ising qft bv qsvm ghz_state qaoa
                      random (deep seeded random circuit; error-control workload)

EXIT CODES: 0 ok | 2 config/usage | 3 storage tier (spill I/O, corruption,
            OOM) | 4 checkpoint/restore | 1 everything else
"#;

/// A CLI failure: either a usage/argument problem or a typed simulation
/// error. The distinction drives the process exit code, so wrapping
/// scripts (CI chaos jobs, schedulers) can tell "fix the command line"
/// (2) from "the storage tier is unhealthy" (3) from "this checkpoint
/// cannot drive this run" (4) without parsing stderr.
enum CliError {
    Usage(String),
    Sim(Error),
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::Usage(m) => write!(f, "{m}"),
            CliError::Sim(e) => write!(f, "{e}"),
        }
    }
}

impl From<String> for CliError {
    fn from(m: String) -> Self {
        CliError::Usage(m)
    }
}

impl From<&str> for CliError {
    fn from(m: &str) -> Self {
        CliError::Usage(m.into())
    }
}

impl From<Error> for CliError {
    fn from(e: Error) -> Self {
        CliError::Sim(e)
    }
}

impl CliError {
    fn exit_code(&self) -> i32 {
        match self {
            CliError::Usage(_) => 2,
            CliError::Sim(e) => i32::from(e.exit_class()),
        }
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    // `process::exit` on both paths: destructors are deliberately skipped
    // so a run that failed with phase threads wedged (stall watchdog)
    // still terminates instead of hanging in a pool join. Normal runs
    // have already flushed and drained everything they own by here.
    match run_cli(&args) {
        Ok(()) => std::process::exit(0),
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(e.exit_code());
        }
    }
}

fn run_cli(args: &[String]) -> Result<(), CliError> {
    let Some(cmd) = args.first() else {
        println!("{USAGE}");
        return Ok(());
    };
    let opts = Opts::parse(&args[1..])?;
    match cmd.as_str() {
        "run" => cmd_run(&opts),
        "partition" => cmd_partition(&opts),
        "compare" => cmd_compare(&opts),
        "sample" => cmd_sample(&opts),
        "report" => cmd_report(&opts),
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => Err(format!("unknown subcommand {other:?}; try `bmqsim help`").into()),
    }
}

/// Hand-rolled `--key value` / `--flag` option bag.
struct Opts {
    map: HashMap<String, String>,
}

impl Opts {
    fn parse(args: &[String]) -> Result<Self, CliError> {
        let mut map = HashMap::new();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if !a.starts_with("--") {
                return Err(format!("unexpected argument {a:?}").into());
            }
            let key = a.trim_start_matches("--").to_string();
            let flag = matches!(
                key.as_str(),
                "no-compress" | "no-prescan" | "no-fusion" | "no-simd" | "sync-spill"
                    | "overlap" | "no-overlap" | "cross-stage" | "no-cross-stage"
                    | "no-spill-order"
            );
            if flag {
                map.insert(key, "true".into());
                i += 1;
            } else {
                let v = args
                    .get(i + 1)
                    .ok_or_else(|| format!("missing value for --{key}"))?;
                map.insert(key, v.clone());
                i += 2;
            }
        }
        Ok(Opts { map })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.map.get(key).map(|s| s.as_str())
    }

    fn parse_num<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("bad value for --{key}: {v:?}")),
        }
    }

    fn flag(&self, key: &str) -> bool {
        self.get(key).is_some()
    }
}

fn load_circuit(opts: &Opts) -> Result<Circuit, CliError> {
    let seed: u64 = opts.parse_num("seed", 42u64)?;
    if let Some(path) = opts.get("qasm") {
        return Ok(qasm::parse_file(std::path::Path::new(path))?);
    }
    let algo = opts.get("algo").ok_or("need --algo <name> or --qasm <file>")?;
    let n: usize = opts.parse_num("qubits", 0usize)?;
    if n == 0 {
        return Err("need --qubits <n>".into());
    }
    Ok(generators::build(algo, n, seed)?)
}

fn build_config(opts: &Opts) -> Result<SimConfig, CliError> {
    let mut cfg = SimConfig {
        block_qubits: opts.parse_num("block-qubits", 14usize)?,
        inner_size: opts.parse_num("inner-size", 2usize)?,
        ..SimConfig::default()
    };
    let eb: f64 = opts.parse_num("error-bound", 1e-3f64)?;
    cfg.codec = if opts.flag("no-compress") {
        Codec::raw()
    } else {
        let mut c = Codec::pointwise(eb);
        c.prescan = !opts.flag("no-prescan");
        c
    };
    if let Some(t) = opts.get("fidelity-target") {
        let t: f64 = t.parse().map_err(|_| format!("bad --fidelity-target: {t:?}"))?;
        cfg.fidelity_target = Some(t);
    }
    if let Some(p) = opts.get("error-policy") {
        cfg.error_policy = p
            .parse::<bmqsim::compress::budget::ErrorPolicy>()
            .map_err(|e| e.to_string())?;
    }
    cfg.pipeline = PipelineConfig::new(
        opts.parse_num("devices", 1usize)?,
        opts.parse_num("streams", 2usize)?,
    );
    if opts.flag("no-fusion") {
        cfg.fusion = false;
    }
    if opts.flag("no-simd") {
        cfg.no_simd = true;
    }
    cfg.max_fuse_qubits = opts.parse_num("max-fuse", cfg.max_fuse_qubits)?;
    cfg.tile_bits = opts.parse_num("tile-bits", cfg.tile_bits)?;
    cfg.apply_workers = opts.parse_num("apply-workers", cfg.apply_workers)?;
    if let Some(mb) = opts.get("memory-budget") {
        let mb: usize = mb.parse().map_err(|_| "bad --memory-budget")?;
        cfg.memory_budget = Some(mb * (1 << 20));
    }
    if let Some(dir) = opts.get("spill-dir") {
        cfg.spill_dir = Some(dir.into());
    }
    if let Some(dir) = opts.get("spill-fallback-dir") {
        cfg.spill_fallback_dir = Some(dir.into());
    }
    if let Some(spec) = opts.get("fault-plan") {
        cfg.fault_plan = Some(bmqsim::memory::FaultPlan::parse(spec)?);
    }
    if let Some(dir) = opts.get("checkpoint-dir") {
        cfg.checkpoint_dir = Some(dir.into());
    }
    cfg.checkpoint_every = opts.parse_num("checkpoint-every", cfg.checkpoint_every)?;
    cfg.checkpoint_keep = opts.parse_num("checkpoint-keep", cfg.checkpoint_keep)?;
    if let Some(dir) = opts.get("resume") {
        cfg.resume_from = Some(dir.into());
    }
    if let Some(ms) = opts.get("stall-timeout-ms") {
        let ms: u64 = ms.parse().map_err(|_| format!("bad --stall-timeout-ms: {ms:?}"))?;
        cfg.stall_timeout_ms = Some(ms);
    }
    cfg.store_shards = opts.parse_num("store-shards", cfg.store_shards)?;
    // Explicit --prefetch-depth pins the depth; omitting it engages the
    // per-stage AIMD auto-depth controller (ROADMAP "prefetch auto-depth").
    match opts.get("prefetch-depth") {
        Some(_) => {
            cfg.prefetch_depth = opts.parse_num("prefetch-depth", cfg.prefetch_depth)?;
            cfg.prefetch_auto = false;
        }
        None => cfg.prefetch_auto = true,
    }
    if opts.flag("sync-spill") {
        cfg.sync_spill = true;
    }
    // --overlap / --no-overlap pin the pipeline; omitting both leaves the
    // per-stage auto-enable heuristic in charge (the default).
    cfg.overlap = match (opts.flag("overlap"), opts.flag("no-overlap")) {
        (true, true) => return Err("--overlap conflicts with --no-overlap".into()),
        (true, false) => OverlapMode::On,
        (false, true) => OverlapMode::Off,
        (false, false) => OverlapMode::Auto,
    };
    // --cross-stage / --no-cross-stage pin the boundary behaviour;
    // omitting both follows the overlap mode (on unless overlap is
    // pinned off).
    cfg.cross_stage = match (opts.flag("cross-stage"), opts.flag("no-cross-stage")) {
        (true, true) => return Err("--cross-stage conflicts with --no-cross-stage".into()),
        (true, false) => OverlapMode::On,
        (false, true) => OverlapMode::Off,
        (false, false) => OverlapMode::Auto,
    };
    // Explicit --pipeline-depth pins the ring depth; omitting it engages
    // the per-stage AIMD controller (ROADMAP "adaptive ring depth").
    match opts.get("pipeline-depth") {
        Some(_) => {
            cfg.pipeline_depth = opts.parse_num("pipeline-depth", cfg.pipeline_depth)?;
            cfg.pipeline_depth_auto = false;
        }
        None => cfg.pipeline_depth_auto = true,
    }
    if opts.flag("no-spill-order") {
        cfg.spill_aware = false;
    }
    if let Some(dir) = opts.get("artifacts") {
        cfg.artifacts_dir = dir.into();
    }
    cfg.backend = opts
        .get("backend")
        .unwrap_or("native")
        .parse::<Backend>()
        .map_err(|e| e.to_string())?;
    Ok(cfg)
}

/// Run the chosen engine, routing through the XLA applier when requested.
fn run_engine(
    opts: &Opts,
    circuit: &Circuit,
    cfg: &SimConfig,
    materialize: bool,
) -> Result<SimResult, CliError> {
    run_engine_with_digest(opts, circuit, cfg, materialize).map(|(r, _)| r)
}

/// [`run_engine`], additionally computing — for the bmqsim engine, whose
/// terminal state stays compressed in the store — an xxh64 digest over
/// every terminal block payload in id order. Byte-identical runs (e.g. an
/// uninterrupted run vs a killed-and-resumed one) print the same digest,
/// which is what the CI resume-chaos job diffs.
fn run_engine_with_digest(
    opts: &Opts,
    circuit: &Circuit,
    cfg: &SimConfig,
    materialize: bool,
) -> Result<(SimResult, Option<u64>), CliError> {
    let engine = opts.get("engine").unwrap_or("bmqsim");
    let xla = match cfg.backend {
        Backend::Xla => Some(XlaApplier::new(cfg.artifacts_dir.clone())?),
        Backend::Native => None,
    };
    if engine == "bmqsim" {
        let sim = match &xla {
            None => BmqSim::new(cfg.clone()),
            Some(x) => BmqSim::with_applier(cfg.clone(), x),
        };
        let (r, store, layout) = sim.run_with_store(circuit, materialize)?;
        let mut digest = 0u64;
        for id in 0..layout.num_blocks() {
            let p = store.get(id)?;
            digest = xxh64(&p.re, digest);
            digest = xxh64(&p.im, digest);
        }
        return Ok((r, Some(digest)));
    }
    let r = match (engine, &xla) {
        ("dense", None) => DenseSim::new(cfg.clone()).run(circuit),
        ("dense", Some(x)) => DenseSim::with_applier(cfg.clone(), x).run(circuit),
        ("sc19-cpu", None) => Sc19Sim::new(cfg.clone(), 1).run(circuit, materialize),
        ("sc19-gpu", None) => Sc19Sim::new(cfg.clone(), 4).run(circuit, materialize),
        (e, Some(_)) => return Err(format!("engine {e:?} has no xla backend").into()),
        (e, None) => return Err(format!("unknown engine {e:?}").into()),
    };
    Ok((r?, None))
}

fn cmd_run(opts: &Opts) -> Result<(), CliError> {
    let circuit = load_circuit(opts)?;
    let cfg = build_config(opts)?;
    println!(
        "running {} ({} qubits, {} gates) on engine={} backend={:?}",
        circuit.name,
        circuit.n_qubits,
        circuit.len(),
        opts.get("engine").unwrap_or("bmqsim"),
        cfg.backend,
    );
    let (r, digest) = run_engine_with_digest(opts, &circuit, &cfg, false)?;
    println!("\n{}", r.metrics);
    if let Some(d) = digest {
        // Terminal compressed state, one line, machine-diffable: the
        // resume-chaos CI job compares this between an uninterrupted run
        // and a SIGKILLed + resumed one.
        println!("state digest     : {d:016x}");
    }
    println!("stages           : {:>10}", r.stages);
    println!(
        "standard memory  : {:>10}",
        fmt_bytes(standard_memory_bytes(circuit.n_qubits, Precision::F64))
    );
    println!("peak compressed  : {:>10}", fmt_bytes(r.peak_bytes as u128));
    if r.mem.spill_events > 0 {
        println!(
            "spill events     : {:>10}  ({:.0}% of blocks on secondary tier)",
            r.mem.spill_events,
            100.0 * r.mem.secondary_fraction()
        );
        println!(
            "evictions        : {:>10}  (prefetch {} hit / {} miss = {:.0}% hit rate, {:.1} ms stalled)",
            r.mem.evictions,
            r.mem.prefetch_hits,
            r.mem.prefetch_misses,
            100.0 * r.mem.prefetch_hit_rate(),
            r.mem.spill_stall_ns as f64 * 1e-6,
        );
        println!(
            "prefetch depth   : {:>10}{}",
            r.mem.prefetch_depth,
            if cfg.prefetch_auto { "  (auto-adapted)" } else { "" }
        );
    }
    let recovered = r.mem.io_retries
        + r.mem.checksum_failures
        + r.mem.frames_recovered
        + r.mem.enospc_fallbacks;
    if recovered > 0 {
        println!(
            "spill recovery   : {:>10}  ({} I/O retries, {} checksum failures, {} frames recovered, {} ENOSPC fallbacks)",
            recovered,
            r.mem.io_retries,
            r.mem.checksum_failures,
            r.mem.frames_recovered,
            r.mem.enospc_fallbacks,
        );
    }
    Ok(())
}

fn cmd_partition(opts: &Opts) -> Result<(), CliError> {
    let circuit = load_circuit(opts)?;
    let b: usize = opts.parse_num("block-qubits", 14usize)?;
    let inner: usize = opts.parse_num("inner-size", 2usize)?;
    let b = b.min(circuit.n_qubits);
    let plan = partition_circuit(&circuit, b, inner)?;
    println!(
        "{}: {} gates -> {} stages (block_qubits={b}, inner_size={}, {} blocks)",
        circuit.name,
        circuit.len(),
        plan.stages.len(),
        plan.inner_size,
        plan.total_blocks(),
    );
    for (i, s) in plan.stages.iter().enumerate() {
        println!(
            "  stage {i:>3}: {:>4} gates, inner globals {:?} -> {} groups x {} blocks",
            s.gates.len(),
            s.inner,
            plan.groups_in_stage(s),
            s.group_blocks(),
        );
    }
    println!(
        "\ncompression rounds: {} (vs {} per-gate)",
        plan.compression_rounds(),
        circuit.len()
    );
    Ok(())
}

fn cmd_compare(opts: &Opts) -> Result<(), CliError> {
    let circuit = load_circuit(opts)?;
    let cfg = build_config(opts)?;
    let ideal = DenseSim::new(SimConfig::default()).run(&circuit)?.state.unwrap();
    let r = run_engine(opts, &circuit, &cfg, true)?;
    let st = r.state.as_ref().ok_or("engine did not materialize state")?;
    println!("engine           : {}", r.engine);
    println!("fidelity         : {:.9} (paper metric |<ideal|sim>|)", st.fidelity(&ideal));
    println!("fidelity (norm.) : {:.9}", st.fidelity_normalized(&ideal));
    println!("wall time        : {:.3} s", r.wall_secs);
    println!("compression ratio: {:.2}x", r.metrics.compression_ratio());
    Ok(())
}

fn cmd_sample(opts: &Opts) -> Result<(), CliError> {
    let circuit = load_circuit(opts)?;
    let cfg = build_config(opts)?;
    let shots: usize = opts.parse_num("shots", 1024usize)?;
    let seed: u64 = opts.parse_num("seed", 42u64)?;
    let r = run_engine(opts, &circuit, &cfg, true)?;
    let st = r.state.as_ref().ok_or("engine did not materialize state")?;
    let mut rng = SplitMix64::new(seed ^ 0x5A11);
    let counts = measure::sample_counts(st, shots, &mut rng);
    println!("top outcomes of {shots} shots:");
    let mut rows: Vec<(usize, usize)> = counts.into_iter().collect();
    rows.sort_by_key(|&(_, c)| std::cmp::Reverse(c));
    for (idx, count) in rows.into_iter().take(16) {
        println!(
            "  |{idx:0w$b}> : {count:>7}  ({:.2}%)",
            100.0 * count as f64 / shots as f64,
            w = circuit.n_qubits
        );
    }
    Ok(())
}

fn cmd_report(opts: &Opts) -> Result<(), CliError> {
    let scale = opts.get("scale").unwrap_or("small");
    let (ns, n_mid, budget) = match scale {
        "small" => (vec![12usize, 14], 14usize, 1usize << 22),
        "full" => (vec![16usize, 18, 20], 20usize, 1usize << 26),
        other => return Err(format!("unknown --scale {other:?}").into()),
    };
    let algos: Vec<&str> = generators::ALL.to_vec();
    let short: Vec<&str> = vec!["qft", "qaoa", "ising", "ghz_state"];

    bench::print_experiment("Table 2: max qubits under memory budget", || {
        Ok(vec![bench::table2_max_qubits(budget, n_mid + 6)?])
    });
    bench::print_experiment("Fig 7: SC19-Sim vs BMQSIM time", || {
        Ok(vec![bench::fig07_sc19_compare(&short, &ns[..1])?])
    });
    bench::print_experiment("Fig 8: fidelity", || {
        Ok(vec![bench::fig08_fidelity(&short, &ns[..1])?])
    });
    bench::print_experiment("Fig 8b: adaptive error-control frontier", || {
        let (n, b) = if scale == "full" { (12, 6) } else { (10, 5) };
        Ok(vec![bench::fig08_frontier(n, b, 0.999)?.0])
    });
    bench::print_experiment("Fig 9: memory consumption (+ §5.4 spill)", || {
        let (a, b) = bench::fig09_memory(&algos, &ns, budget / 64)?;
        Ok(vec![a, b])
    });
    bench::print_experiment("Fig 10: simulation time vs dense", || {
        Ok(vec![bench::fig10_simtime(&algos, &ns)?])
    });
    bench::print_experiment("Fig 11: compression overhead", || {
        Ok(vec![bench::fig11_comp_overhead(&algos, &ns)?])
    });
    bench::print_experiment("Fig 12: stream count", || {
        Ok(vec![
            bench::fig12_streams(&short, n_mid, false)?,
            bench::fig12_streams(&short, n_mid, true)?,
        ])
    });
    bench::print_experiment("Fig 13: device scaling", || {
        Ok(vec![bench::fig13_scaling(&short, n_mid)?])
    });
    bench::print_experiment("Fig 14: partition overhead", || {
        Ok(vec![bench::fig14_partition_overhead(&algos, n_mid)?])
    });
    bench::print_experiment("Fig 15: parameter tuning", || {
        let (a, b) = bench::fig15_params("qaoa", n_mid, &[2, 3, 4], &[8, 10, 12])?;
        Ok(vec![a, b])
    });
    bench::print_experiment("Ablation A1: bitmap pre-scan", || {
        Ok(vec![bench::ablation_prescan(1 << 14)?])
    });
    bench::print_experiment("Ablation A2: error-control mode", || {
        Ok(vec![bench::ablation_error_mode("ising", n_mid)?])
    });
    Ok(())
}
